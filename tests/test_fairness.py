"""Fair bandwidth sharing: max-min, weighted (WFQ), strict-priority, and
deficit-round-robin allocator properties — conservation, bottleneck
saturation, weight monotonicity, no-starvation, priority dominance, and
the bit-exact uniform reductions — plus engine-level byte conservation,
offered-bytes equivalence for symmetric demands, and the documented
no-starvation direction versus the offered-bytes split.

The allocator invariants run twice: as seeded random sweeps (always on, no
optional deps) and as hypothesis property tests when hypothesis is
installed (see requirements-dev.txt)."""
import math
import random

import pytest

from repro.fabric import CongestionConfig, FabricEngine, JobSpec, fat_tree
from repro.fabric.congestion import (drr_share, drr_shares, maxmin_shares,
                                     strict_priority_share,
                                     strict_priority_shares, wfq_share,
                                     wfq_shares)
from repro.fabric.stragglers import StragglerConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                   # tier-1 degrades gracefully
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("demands,capacity", [
    ([1.0, 1.0], 1.0),
    ([0.2, 0.9, 1.0], 1.0),
    ([0.1, 0.1, 0.1], 1.0),
    ([1.0], 1.0),
    ([0.5, 0.5, 0.5, 0.5], 1.0),
    ([2.0, 0.25, 1.0], 2.0),
])
def test_maxmin_invariants(demands, capacity):
    alloc = maxmin_shares(demands, capacity)
    n = len(demands)
    # never above demand; never starved below the bottleneck share
    for a, d in zip(alloc, demands):
        assert a <= d + 1e-12
        assert a >= min(d, capacity / n) - 1e-12
    # bottleneck saturation: link fills iff total demand >= capacity
    assert sum(alloc) == pytest.approx(min(capacity, sum(demands)))


def test_maxmin_symmetric_demands_split_equally():
    alloc = maxmin_shares([0.8, 0.8, 0.8])
    assert alloc[1] == pytest.approx(alloc[0])
    assert alloc[2] == pytest.approx(alloc[0])


def test_maxmin_small_flow_keeps_its_demand():
    # progressive filling: the small flow is satisfied, the big flows split
    # the rest — offered-bytes would scale everyone by byte volume instead
    alloc = maxmin_shares([0.1, 5.0, 5.0])
    assert alloc[0] == pytest.approx(0.1)
    assert alloc[1] == alloc[2] == pytest.approx(0.45)


def test_maxmin_random_sweep_properties():
    rng = random.Random(7)
    for _ in range(200):
        n = rng.randint(1, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        alloc = maxmin_shares(demands)
        assert sum(alloc) == pytest.approx(min(1.0, sum(demands)))
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-12
            assert a >= min(d, 1.0 / n) - 1e-12


def test_engine_rejects_unknown_fairness():
    with pytest.raises(KeyError):
        FabricEngine(fat_tree(16), [JobSpec("a", 4)], fairness="bogus")


# ---------------------------------------------------------------------------
# weighted (WFQ) allocator properties
# ---------------------------------------------------------------------------


def _check_wfq_invariants(demands, weights, capacity=1.0):
    alloc = wfq_shares(demands, weights, capacity)
    n = len(demands)
    total_w = sum(weights)
    # conservation / bottleneck saturation
    assert sum(alloc) == pytest.approx(min(capacity, sum(demands)))
    for a, d, w in zip(alloc, demands, weights):
        # never above demand
        assert a <= d + 1e-9
        # weighted no-starvation: at least the weighted bottleneck share
        assert a >= min(d, capacity * w / total_w) - 1e-9
    return alloc


def test_wfq_uniform_weights_bit_identical_to_maxmin():
    """The acceptance-criteria reduction: weight-1 everywhere is the same
    arithmetic as maxmin_shares, so the result is `==`, not approx."""
    rng = random.Random(11)
    for _ in range(300):
        n = rng.randint(0, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        capacity = rng.choice([1.0, 0.7, 2.5])
        assert wfq_shares(demands, [1.0] * n, capacity) \
            == maxmin_shares(demands, capacity)
        assert wfq_shares(demands, None, capacity) \
            == maxmin_shares(demands, capacity)


def test_wfq_share_uniform_weights_bit_identical_to_maxmin_share():
    from repro.fabric.congestion import maxmin_share
    rng = random.Random(13)
    for _ in range(100):
        d_i = 0.05 + rng.random()
        ovs = [rng.random() * d_i * 2 for _ in range(rng.randint(0, 5))]
        assert wfq_share(d_i, 1.0, [(ov, 1.0) for ov in ovs]) \
            == maxmin_share(d_i, ovs)


def test_wfq_random_sweep_invariants():
    rng = random.Random(17)
    for _ in range(300):
        n = rng.randint(1, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        weights = [0.1 + rng.random() * 8.0 for _ in range(n)]
        _check_wfq_invariants(demands, weights,
                              capacity=rng.choice([1.0, 0.5, 3.0]))


def test_wfq_monotone_in_weight():
    """Raising one flow's weight never shrinks its allocation."""
    rng = random.Random(19)
    for _ in range(200):
        n = rng.randint(2, 6)
        demands = [rng.random() * 2.0 for _ in range(n)]
        weights = [0.1 + rng.random() * 4.0 for _ in range(n)]
        j = rng.randrange(n)
        lo = wfq_shares(demands, weights)[j]
        weights[j] *= 1.0 + rng.random() * 4.0
        hi = wfq_shares(demands, weights)[j]
        assert hi >= lo - 1e-9


def test_wfq_splits_saturated_link_by_weight():
    # all flows saturated: allocation is exactly proportional to weight
    alloc = wfq_shares([1.0, 1.0, 1.0], [1.0, 2.0, 5.0])
    assert alloc == pytest.approx([1 / 8, 2 / 8, 5 / 8])


def test_wfq_heavy_weight_cannot_exceed_its_demand():
    # weight buys priority, not free bandwidth: the heavy-weight small
    # flow is capped at its demand, leftovers go to the others
    alloc = wfq_shares([0.1, 1.0, 1.0], [100.0, 1.0, 1.0])
    assert alloc[0] == pytest.approx(0.1)
    assert alloc[1] == alloc[2] == pytest.approx(0.45)


def test_specs_reject_non_positive_weight():
    # caught at construction, not deep inside algo selection / allocation
    from repro.fabric import InferenceSpec
    for w in (0.0, -1.0):
        with pytest.raises(ValueError):
            JobSpec("a", 4, weight=w)
        with pytest.raises(ValueError):
            InferenceSpec("s", 4, weight=w)


def test_wfq_rejects_bad_inputs():
    with pytest.raises(ValueError):
        wfq_shares([1.0, 1.0], [1.0])            # length mismatch
    with pytest.raises(ValueError):
        wfq_shares([1.0], [0.0])                 # non-positive weight
    with pytest.raises(ValueError):
        wfq_shares([1.0], [-2.0])
    assert wfq_shares([], []) == []


# ---------------------------------------------------------------------------
# strict-priority allocator properties
# ---------------------------------------------------------------------------


def test_strict_priority_serves_classes_in_order():
    # the high class takes its full demand; the low class gets leftovers
    alloc = strict_priority_shares([0.8, 0.8], [5, 0])
    assert alloc == pytest.approx([0.8, 0.2])
    # a saturated high class starves the low one entirely
    alloc = strict_priority_shares([1.5, 0.5], [5, 0])
    assert alloc == pytest.approx([1.0, 0.0])
    # max-min within a class: small same-class flow keeps its demand
    alloc = strict_priority_shares([0.1, 5.0, 5.0], [3, 3, 3])
    assert alloc == pytest.approx([0.1, 0.45, 0.45])


def test_strict_priority_random_sweep_invariants():
    rng = random.Random(23)
    for _ in range(300):
        n = rng.randint(1, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        prios = [rng.randint(0, 3) for _ in range(n)]
        capacity = rng.choice([1.0, 0.5, 3.0])
        alloc = strict_priority_shares(demands, prios, capacity)
        # conservation / bottleneck saturation, never above demand
        assert sum(alloc) == pytest.approx(min(capacity, sum(demands)))
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-9
        # dominance: a class receives nothing until every higher class
        # is at its demand
        for j in range(n):
            if alloc[j] > 1e-12:
                for k in range(n):
                    if prios[k] > prios[j]:
                        assert alloc[k] == pytest.approx(demands[k])


def test_strict_priority_uniform_reduces_bit_exactly_to_maxmin():
    rng = random.Random(29)
    for _ in range(200):
        n = rng.randint(0, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        capacity = rng.choice([1.0, 0.7, 2.5])
        prio = rng.choice([0, 1, 7])
        assert strict_priority_shares(demands, [prio] * n, capacity) \
            == maxmin_shares(demands, capacity)


def test_strict_priority_share_uniform_reduces_to_maxmin_share():
    from repro.fabric.congestion import maxmin_share
    rng = random.Random(31)
    for _ in range(100):
        d_i = 0.05 + rng.random()
        ovs = [rng.random() * d_i * 2 for _ in range(rng.randint(0, 5))]
        assert strict_priority_share(d_i, 0, [(ov, 0) for ov in ovs]) \
            == maxmin_share(d_i, ovs)


def test_strict_priority_rejects_length_mismatch():
    with pytest.raises(ValueError):
        strict_priority_shares([1.0, 1.0], [1])


# ---------------------------------------------------------------------------
# deficit-round-robin allocator properties
# ---------------------------------------------------------------------------


def test_drr_random_sweep_conservation_and_saturation():
    rng = random.Random(37)
    for _ in range(200):
        n = rng.randint(1, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        weights = [0.05 + rng.random() * 8.0 for _ in range(n)]
        capacity = rng.choice([1.0, 0.5, 3.0])
        alloc = drr_shares(demands, weights, capacity)
        assert sum(alloc) == pytest.approx(min(capacity, sum(demands)))
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-9
            assert a >= 0.0


def test_drr_uniform_weights_reduce_to_maxmin_within_one_quantum():
    """DRR is quantized max-min at uniform weights: the ring-order
    discretization can shift a flow by at most one quantum
    (capacity / rounds)."""
    rng = random.Random(41)
    for _ in range(200):
        n = rng.randint(1, 8)
        demands = [rng.random() * 2.0 for _ in range(n)]
        capacity = rng.choice([1.0, 2.5])
        quantum = capacity / 64
        alloc = drr_shares(demands, [1.0] * n, capacity)
        ref = maxmin_shares(demands, capacity)
        for a, r in zip(alloc, ref):
            assert a == pytest.approx(r, abs=2 * quantum)


def test_drr_weight_scales_the_saturated_share():
    # all flows saturated: allocation tracks weight (within quantum)
    alloc = drr_shares([1.0, 1.0], [1.0, 3.0])
    assert alloc[1] > alloc[0]
    assert alloc[1] / alloc[0] == pytest.approx(3.0, rel=0.15)
    # weight buys priority, not free bandwidth
    alloc = drr_shares([0.05, 1.0, 1.0], [100.0, 1.0, 1.0])
    assert alloc[0] == pytest.approx(0.05)


def test_drr_converges_to_wfq_as_the_quantum_shrinks():
    demands = [1.2, 0.3, 0.9]
    weights = [1.0, 2.0, 4.0]
    fluid = wfq_shares(demands, weights)
    coarse = drr_shares(demands, weights, rounds=8)
    fine = drr_shares(demands, weights, rounds=4096)
    err = [abs(a - f) for a, f in zip(coarse, fluid)]
    err_fine = [abs(a - f) for a, f in zip(fine, fluid)]
    assert max(err_fine) < max(err)
    assert max(err_fine) < 1e-3


def test_drr_rejects_bad_inputs():
    with pytest.raises(ValueError):
        drr_shares([1.0, 1.0], [1.0])            # length mismatch
    with pytest.raises(ValueError):
        drr_shares([1.0], [0.0])                 # non-positive weight
    with pytest.raises(ValueError):
        drr_shares([1.0], [1.0], rounds=0)
    assert drr_shares([], []) == []


def test_drr_share_window_model_matches_wfq_shape():
    # one heavy co-owner: the DRR share lands near the WFQ fluid share
    share = drr_share(1.0, 1.0, [(1.0, 1.0)])
    assert share == pytest.approx(0.5, abs=0.05)
    hi = drr_share(1.0, 4.0, [(1.0, 1.0)])
    assert hi > share


if HAVE_HYPOTHESIS:
    finite = dict(allow_nan=False, allow_infinity=False)
    _demands = st.lists(st.floats(min_value=0.0, max_value=50.0, **finite),
                        min_size=1, max_size=12)

    @given(demands=_demands,
           data=st.data(),
           capacity=st.floats(min_value=1e-3, max_value=100.0, **finite))
    @settings(max_examples=150, deadline=None)
    def test_hyp_wfq_invariants(demands, data, capacity):
        weights = data.draw(st.lists(
            st.floats(min_value=1e-3, max_value=100.0, **finite),
            min_size=len(demands), max_size=len(demands)))
        _check_wfq_invariants(demands, weights, capacity)

    @given(demands=_demands,
           capacity=st.floats(min_value=1e-3, max_value=100.0, **finite))
    @settings(max_examples=150, deadline=None)
    def test_hyp_wfq_uniform_reduces_bit_exactly(demands, capacity):
        assert wfq_shares(demands, [1.0] * len(demands), capacity) \
            == maxmin_shares(demands, capacity)

    @given(demands=_demands, data=st.data(),
           factor=st.floats(min_value=1.0, max_value=50.0, **finite))
    @settings(max_examples=150, deadline=None)
    def test_hyp_wfq_monotone_in_weight(demands, data, factor):
        n = len(demands)
        weights = data.draw(st.lists(
            st.floats(min_value=1e-3, max_value=100.0, **finite),
            min_size=n, max_size=n))
        j = data.draw(st.integers(min_value=0, max_value=n - 1))
        lo = wfq_shares(demands, weights)[j]
        weights[j] *= factor
        hi = wfq_shares(demands, weights)[j]
        assert hi >= lo - 1e-9 * max(1.0, lo)

    @given(demands=_demands, data=st.data(),
           capacity=st.floats(min_value=1e-3, max_value=100.0, **finite))
    @settings(max_examples=150, deadline=None)
    def test_hyp_strict_priority_invariants(demands, data, capacity):
        prios = data.draw(st.lists(
            st.integers(min_value=0, max_value=4),
            min_size=len(demands), max_size=len(demands)))
        alloc = strict_priority_shares(demands, prios, capacity)
        assert sum(alloc) == pytest.approx(min(capacity, sum(demands)),
                                           rel=1e-9, abs=1e-12)
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-9 * max(1.0, d)

    @given(demands=_demands,
           prio=st.integers(min_value=0, max_value=9),
           capacity=st.floats(min_value=1e-3, max_value=100.0, **finite))
    @settings(max_examples=150, deadline=None)
    def test_hyp_strict_priority_uniform_reduces_bit_exactly(
            demands, prio, capacity):
        assert strict_priority_shares(demands, [prio] * len(demands),
                                      capacity) \
            == maxmin_shares(demands, capacity)

    @given(demands=_demands, data=st.data(),
           capacity=st.floats(min_value=1e-3, max_value=100.0, **finite))
    @settings(max_examples=100, deadline=None)
    def test_hyp_drr_conservation(demands, data, capacity):
        weights = data.draw(st.lists(
            st.floats(min_value=1e-3, max_value=100.0, **finite),
            min_size=len(demands), max_size=len(demands)))
        alloc = drr_shares(demands, weights, capacity)
        assert sum(alloc) == pytest.approx(min(capacity, sum(demands)),
                                           rel=1e-9, abs=1e-12)
        for a, d in zip(alloc, demands):
            assert a <= d + 1e-9 * max(1.0, d)


# ---------------------------------------------------------------------------
# engine-level properties
# ---------------------------------------------------------------------------


def _fabric():
    return fat_tree(64, nodes_per_leaf=8)


def test_maxmin_conserves_link_bytes():
    jobs = [JobSpec("a", 8, placement="scattered"),
            JobSpec("b", 8, placement="scattered", grad_bytes=2e9),
            JobSpec("c", 8, placement="compact", algo="tree")]
    res = FabricEngine(_fabric(), jobs, base_seed=1,
                       fairness="maxmin").run(120, warmup=10)
    per_job = {}
    for jr in res.jobs:
        for ln, b in jr.link_bytes.items():
            per_job[ln] = per_job.get(ln, 0.0) + b
    assert set(per_job) == set(res.link_bytes)
    for ln, total in res.link_bytes.items():
        assert per_job[ln] == pytest.approx(total, rel=1e-9)


def test_maxmin_equals_offered_for_symmetric_demands():
    """Two identical deterministic jobs, symmetric placements, uniform
    background congestion: every contended link sees two equal flows in
    full overlap, so both fairness models give each flow exactly half and
    the step-time series coincide (up to ulp noise in the share
    arithmetic, hence approx, not ==)."""
    det = StragglerConfig(jitter_sigma=0.0, locality_spread=0.0,
                          spike_prob=0.0)
    cong = CongestionConfig(u_sigma=0.0)
    jobs = [JobSpec("a", 12, nodes=tuple(range(12)), stragglers=det),
            JobSpec("b", 12, nodes=tuple(range(12, 24)), stragglers=det)]

    def series(fairness):
        res = FabricEngine(_fabric(), jobs, base_seed=0, congestion=cong,
                           fairness=fairness).run(80, warmup=10)
        return [res.job("a").step_times, res.job("b").step_times]

    offered, maxmin = series("offered"), series("maxmin")
    for so, sm in zip(offered, maxmin):
        assert sm == pytest.approx(so, rel=1e-9)
    # and the contention is real: both exceed the solo baseline
    solo = FabricEngine(_fabric(), [jobs[0]], base_seed=0,
                        congestion=cong).run(80, warmup=10)
    assert maxmin[0][0] > solo.job("a").step_times[0]


def test_engine_wfq_uniform_weights_bit_identical_to_maxmin():
    """fairness="wfq" with default weights must be the max-min engine
    bit-for-bit (list equality, not approx) — the engine-level face of the
    allocator's uniform-weight reduction."""
    jobs = [JobSpec("a", 8, placement="scattered"),
            JobSpec("b", 8, placement="scattered", grad_bytes=2e9),
            JobSpec("c", 8, placement="compact", algo="tree")]

    def series(fairness):
        res = FabricEngine(_fabric(), jobs, base_seed=3,
                           fairness=fairness).run(100, warmup=10)
        return [res.job(s.name).step_times for s in jobs]

    assert series("wfq") == series("maxmin")


def test_engine_wfq_weight_buys_bandwidth():
    """Two clones contending on the same up-links, one carrying 16x the
    weight: the heavy tenant's contended windows widen to ~w/(w+1) of the
    link, so its steps shrink versus the unweighted split. (BSP traffic is
    closed-loop — the faster heavy tenant also occupies the link *less*,
    so the light co-tenant is not necessarily slower overall; the
    open-loop trade lives in the lifecycle WFQ tests/benchmark.)"""
    def mean_steps(w_a, w_b, fairness="wfq"):
        jobs = [JobSpec("a", 12, nodes=tuple(range(12)), grad_bytes=4e9,
                        weight=w_a),
                JobSpec("b", 12, nodes=tuple(range(12, 24)), grad_bytes=4e9,
                        weight=w_b)]
        res = FabricEngine(_fabric(), jobs, base_seed=0,
                           fairness=fairness).run(120, warmup=20)
        return res.job("a").mean_step, res.job("b").mean_step

    eq_a, eq_b = mean_steps(1.0, 1.0)
    hi_a, _ = mean_steps(16.0, 1.0)
    assert hi_a < eq_a                # weight buys bandwidth
    # symmetric: the same weight on the other tenant speeds *it* up
    _, hi_b = mean_steps(1.0, 16.0)
    assert hi_b < eq_b
    # weights only matter under wfq: maxmin ignores them entirely
    mm_a, mm_b = mean_steps(16.0, 1.0, fairness="maxmin")
    assert (mm_a, mm_b) == (eq_a, eq_b)


def test_unweighted_modes_ignore_weight_even_with_auto_algo():
    """JobSpec.weight is documented as ignored by the unweighted fairness
    modes — including the algo="auto" selection path, which must not
    optimize for a contended share that maxmin will never grant."""
    def series(w):
        jobs = [JobSpec("a", 12, placement="scattered", algo="auto",
                        weight=w),
                JobSpec("b", 12, placement="scattered", grad_bytes=2e9)]
        res = FabricEngine(_fabric(), jobs, base_seed=0,
                           fairness="maxmin").run(60, warmup=5)
        return res.job("a").algo, res.job("a").step_times

    assert series(8.0) == series(1.0)


def test_maxmin_never_starves_the_small_flow():
    """The documented direction of the model change: offered-bytes scales a
    flow's share by its byte volume, so a small-payload job sharing up1
    with an 8 GB co-tenant is starved toward zero bandwidth; max-min gives
    every active flow at least its bottleneck share of the link."""
    small = JobSpec("small", 12, nodes=tuple(range(12)), grad_bytes=2e8)
    big = JobSpec("big", 12, nodes=tuple(range(12, 24)), grad_bytes=8e9)

    def mean(fairness, name):
        res = FabricEngine(_fabric(), [small, big], base_seed=0,
                           fairness=fairness).run(150, warmup=20)
        return res.job(name).mean_step

    solo = FabricEngine(_fabric(), [small], base_seed=0) \
        .run(150, warmup=20).job("small").mean_step
    offered_small, maxmin_small = mean("offered", "small"), \
        mean("maxmin", "small")
    # max-min protects the small flow...
    assert maxmin_small < 0.7 * offered_small
    # ...while both models still charge it real contention
    assert maxmin_small > solo
    # and the heavy flow pays (weakly) for the protection
    assert mean("maxmin", "big") >= 0.95 * mean("offered", "big")


# ---------------------------------------------------------------------------
# the new registry modes through the engines
# ---------------------------------------------------------------------------


def _contending_pair(prio_a=0, prio_b=0, w_a=1.0, w_b=1.0):
    return [JobSpec("a", 12, nodes=tuple(range(12)), grad_bytes=4e9,
                    priority=prio_a, weight=w_a),
            JobSpec("b", 12, nodes=tuple(range(12, 24)), grad_bytes=4e9,
                    priority=prio_b, weight=w_b)]


def test_engine_strict_priority_uniform_is_bit_identical_to_maxmin():
    """Uniform priorities collapse to one class = one maxmin_shares call:
    the engine-level face of the allocator's bit-exact reduction."""
    def series(fairness):
        res = FabricEngine(_fabric(), _contending_pair(), base_seed=0,
                           fairness=fairness).run(80, warmup=10)
        return [res.job("a").step_times, res.job("b").step_times]

    assert series("strict_priority") == series("maxmin")


def test_engine_strict_priority_protects_the_high_class():
    def mean_steps(fairness, prio_b=0):
        res = FabricEngine(_fabric(), _contending_pair(prio_b=prio_b),
                           base_seed=0, fairness=fairness) \
            .run(100, warmup=10)
        return res.job("a").mean_step, res.job("b").mean_step

    eq_a, eq_b = mean_steps("strict_priority")
    _, hi_b = mean_steps("strict_priority", prio_b=5)
    assert hi_b < eq_b                # priority buys the whole link
    # priorities are inert under the weight-based modes
    assert mean_steps("maxmin", prio_b=5) == mean_steps("maxmin")


def test_engine_strict_priority_survives_total_starvation():
    """Saturated higher classes drive a lower class's allocator share to
    exactly 0.0; the policy floors it at RESIDUAL_SHARE so the starved
    collective still completes (a literal zero share divides the cost
    model by zero). Regression: this configuration crashed with
    ZeroDivisionError before the floor."""
    jobs = [JobSpec("lo", 8, placement="scattered", grad_bytes=6e9,
                    priority=0),
            JobSpec("hi1", 8, placement="scattered", grad_bytes=6e9,
                    priority=5),
            JobSpec("hi2", 8, placement="scattered", grad_bytes=6e9,
                    priority=5)]
    res = FabricEngine(_fabric(), jobs, base_seed=0,
                       fairness="strict_priority").run(300, warmup=10)
    lo = res.job("lo")
    assert all(s > 0.0 and s < float("inf") for s in lo.step_times)
    # the floor itself: two saturated higher-class owners starve the
    # allocator share to exactly 0.0, the policy clamps it
    from repro.fabric.policies import StrictPriorityFairness
    policy = StrictPriorityFairness()
    share = policy.link_share(1.0, 1e9, 1.0, 0, [],
                              [(1.0, 1.0, 5), (1.0, 1.0, 5)])
    assert strict_priority_share(1.0, 0, [(1.0, 5), (1.0, 5)]) == 0.0
    assert share == policy.RESIDUAL_SHARE


def test_engine_drr_weight_buys_bandwidth():
    def mean_a(w_a):
        res = FabricEngine(_fabric(), _contending_pair(w_a=w_a),
                           base_seed=0, fairness="drr").run(100, warmup=10)
        return res.job("a").mean_step

    assert mean_a(8.0) < mean_a(1.0)


def test_fairness_policy_instance_is_accepted_directly():
    from repro.fabric.policies import resolve_fairness
    policy = resolve_fairness("maxmin")
    res = FabricEngine(_fabric(), _contending_pair(), base_seed=0,
                       fairness=policy).run(30, warmup=5)
    ref = FabricEngine(_fabric(), _contending_pair(), base_seed=0,
                       fairness="maxmin").run(30, warmup=5)
    assert res.job("a").step_times == ref.job("a").step_times


# ---------------------------------------------------------------------------
# allocator-boundary validation (backend PR bugfixes)
# ---------------------------------------------------------------------------

# every progressive-filling allocator, normalized to (demands, capacity)
_ALLOCATORS = [
    ("maxmin", lambda d, c: maxmin_shares(d, capacity=c)),
    ("wfq", lambda d, c: wfq_shares(d, capacity=c)),
    ("strict_priority",
     lambda d, c: strict_priority_shares(d, [0.0] * len(d), capacity=c)),
    ("drr", lambda d, c: drr_shares(d, capacity=c)),
]


@pytest.mark.parametrize("alloc", [a for _, a in _ALLOCATORS],
                         ids=[n for n, _ in _ALLOCATORS])
@pytest.mark.parametrize("demands", [
    [-0.1, 0.5],                      # negative rate
    [0.5, float("nan")],              # NaN poisons every comparison
    [float("-inf")],
])
def test_allocators_reject_invalid_demands(alloc, demands):
    """A negative or NaN demand used to flow straight into the
    progressive fill and come out as a negative or NaN *allocation*,
    silently breaking the conservation invariants asserted above. The
    shared boundary check now rejects it at the API edge."""
    with pytest.raises(ValueError, match="demands"):
        alloc(demands, 1.0)


@pytest.mark.parametrize("alloc", [a for _, a in _ALLOCATORS],
                         ids=[n for n, _ in _ALLOCATORS])
@pytest.mark.parametrize("capacity", [-1.0, float("nan")])
def test_allocators_reject_invalid_capacity(alloc, capacity):
    with pytest.raises(ValueError, match="capacity"):
        alloc([0.5, 0.5], capacity)


def test_allocators_accept_zero_demands_and_capacity():
    """The validation must not over-reject: all-zero demands and zero
    capacity are legitimate edge inputs with well-defined allocations."""
    for _, alloc in _ALLOCATORS:
        assert alloc([0.0, 0.0], 1.0) == [0.0, 0.0]
        assert alloc([0.5, 0.5], 0.0) == [0.0, 0.0]


def test_offered_share_zero_byte_collective_floor():
    """Regression: a zero-byte collective next to co-tenant flows got
    share 0.0, which downstream duration division turned into ``inf``
    step times. The share is now floored at RESIDUAL_SHARE, mirroring
    the strict-priority starved-class floor."""
    from repro.fabric.congestion import RESIDUAL_SHARE, offered_share

    share = offered_share(0.0, 1.0, [(1.0, 5.0)])
    assert share == RESIDUAL_SHARE
    assert math.isfinite(1.0 / share)
    # the floor must not disturb the normal proportional split...
    assert offered_share(2.0, 1.0, [(1.0, 2.0)]) == pytest.approx(0.5)
    # ...or the uncontended owner, who keeps the whole link
    assert offered_share(0.0, 1.0, []) == 1.0
