"""Tests for the §Perf hillclimb machinery: variant parsing, microbatch
gradient accumulation, chunked cross-entropy, int8 compression plumbing."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import OptimizerConfig, get_model_config
from repro.launch.dryrun_variants import apply_variant_pure
from repro.launch.steps import make_train_step
from repro.models.api import build_model
from repro.optim import init_opt_state

# Microbatch/loss-chunk equivalence jits full train steps (tens of
# seconds); default tier-1 excludes them, CI's slow job runs them.
pytestmark = pytest.mark.slow


def test_variant_parsing():
    cfg = get_model_config("qwen2-7b", smoke=True)
    out, mb, int8, noz1, rules, env = apply_variant_pure(cfg, "opt+mb8+lc2048")
    assert out.pad_heads_to == 16 and out.loss_chunk == 2048
    assert mb == 8 and not int8 and not noz1
    _, _, int8, _, rules, _ = apply_variant_pure(cfg, "int8pod+seqkv")
    assert int8 and rules == {"seq": "model"}
    _, _, _, noz1, _, env = apply_variant_pure(cfg, "noz1+nf32")
    assert noz1 and env.get("REPRO_NORM_BF16") == "1"
    with pytest.raises(ValueError):
        apply_variant_pure(cfg, "bogus")


def test_loss_chunk_matches_full():
    cfg = get_model_config("mixtral-8x7b", smoke=True)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab_size)
    l_full, _ = m.loss(p, {"tokens": toks})
    mc = build_model(cfg.replace(loss_chunk=8))
    l_chunk, _ = mc.loss(p, {"tokens": toks})
    assert abs(float(l_full) - float(l_chunk)) < 2e-3


def test_microbatch_step_equivalence():
    cfg = get_model_config("qwen2-7b", smoke=True)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    opt = OptimizerConfig(warmup_steps=1, total_steps=4)
    s0 = init_opt_state(opt, p)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                              cfg.vocab_size)
    p1, _, m1 = jax.jit(make_train_step(m, opt))(p, s0, {"tokens": toks})
    p4, _, m4 = jax.jit(make_train_step(m, opt, microbatches=4))(
        p, s0, {"tokens": toks})
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 0.05   # bf16 params; accumulation order differs


def test_tp_row_matmul_identity_when_disabled(monkeypatch):
    from repro.launch import sharding as shd
    monkeypatch.delenv("REPRO_BF16_TP", raising=False)
    h = jnp.ones((2, 3, 8))
    w = jnp.ones((8, 4))
    out = shd.tp_row_matmul(h, w)
    assert out.shape == (2, 3, 4)
    assert bool(jnp.allclose(out, h @ w))
