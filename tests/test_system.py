"""End-to-end behaviour tests for the paper's system: the coordination layer
running over the fabric simulator must reproduce the paper's qualitative
claims (Table 1 / Figures 1 & 5 signatures)."""
import pytest

from repro.core import diagnose
from repro.fabric import SimConfig, efficiency_curve, simulate


def test_paper_end_to_end_signature():
    """The three headline claims, in one run each:
    1. scaling efficiency decays well before hardware limits;
    2. instability (CV) grows with node count;
    3. coordination recovers throughput at scale at negligible small-N cost.
    """
    curve = efficiency_curve([4, 16, 64], coordination=False)
    assert curve[64]["efficiency"] < 0.75
    assert curve[64]["cv"] > curve[4]["cv"]

    base = simulate(SimConfig.paper(64, coordination=False))
    coord = simulate(SimConfig.paper(64, coordination=True))
    assert coord.throughput > 1.04 * base.throughput
    assert coord.cv < 0.8 * base.cv

    small_b = simulate(SimConfig.paper(4, coordination=False))
    small_c = simulate(SimConfig.paper(4, coordination=True))
    assert abs(small_c.throughput / small_b.throughput - 1) < 0.02


def test_diagnostics_attribute_failure_modes_at_scale():
    res = simulate(SimConfig.paper(64, coordination=False))
    rep = diagnose(res.per_rank_records())
    d = rep.to_dict()
    assert set(d["scores"]) == {"sync_amplification", "fabric_contention",
                                "locality_variance", "runtime_jitter"}
    assert len(d["principles"]) >= 4
    # at 64 nodes the coordination-visible modes carry real weight
    assert d["scores"]["sync_amplification"]["score"] > 0.02
    assert d["scores"]["fabric_contention"]["score"] > 0.1


def test_pacing_disengages_in_stable_cluster():
    cfg = SimConfig.paper(16, coordination=True)
    stable = cfg.__class__(
        n_nodes=16, pacing=cfg.pacing, seed=1,
        stragglers=cfg.stragglers.__class__(
            jitter_sigma=0.001, locality_spread=0.0, spike_prob=0.0),
        congestion=cfg.congestion.__class__(
            u_mean=0.0, u_sigma=0.0, k_burst=0.0, ecmp_k=0.0, k_kick=0.0),
    )
    res = simulate(stable)
    total_pacing = sum(r.pacing_delay for recs in res.records for r in recs)
    mean_step = res.mean_step
    assert total_pacing < 0.01 * mean_step * len(res.step_times) * 16
