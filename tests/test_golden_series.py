"""Golden determinism-regression fixtures, replayed through the Scenario
front door.

PR 1/2 established a determinism contract: same seed + same event list =>
bit-identical step-time / latency series, across arrivals, blocked
admissions, failures, and re-placements. The property tests in
``test_lifecycle.py`` check *relations* (prefix equality, inertness); these
tests pin the *absolute* series: small scenarios are serialized (float hex
— bit-exact, no repr rounding) under ``tests/golden/`` and every run must
replay them identically, so a future refactor cannot silently shift the
contract.

Since PR 4 every fixture is built as a declarative
:class:`repro.fabric.scenario.Scenario` and replayed through
``Scenario.run().fingerprint()`` — the fixtures themselves are unchanged
from when they were recorded against the PR-2/PR-3 engines, so a matching
replay *is* the proof that the Scenario path (and the pluggable policy
registries behind it) reproduces the legacy entry points bit-for-bit:
``lifecycle_fifo`` and ``engine_maxmin`` were generated from the PR-2 code
before weighted fair queuing and scheduler policies existed;
``lifecycle_preempt`` and ``lifecycle_wfq`` lock the PR-3 policies.

Regenerate (only when a behavior change is intended and reviewed):

    PYTHONPATH=src python tests/test_golden_series.py
"""
import json
import os

import pytest

from repro.fabric import (Arrival, Departure, InferenceSpec, JobSpec,
                          NodeFailure, Policies, Scenario, TopologySpec)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

FABRIC64 = TopologySpec(kind="fat_tree", n_nodes=64, nodes_per_leaf=8)


# ---------------------------------------------------------------------------
# scenarios: one builder per fixture, shared by the test and the regen entry
# ---------------------------------------------------------------------------


def mixed_lifecycle_events():
    """PR-2 shape: staggered arrivals, an open-loop inference co-tenant, a
    blocked arrival admitted on a departure, and a mid-run node failure.
    Shared with tests/test_scheduling.py so the scheduler-equivalence
    tests exercise exactly the scenario the golden fixture pins."""
    return [
        Arrival(0.0, JobSpec("t0", 12, placement="compact", algo="auto")),
        Arrival(2.0, InferenceSpec("serve", 4, rate_rps=8.0)),
        Arrival(3.0, JobSpec("t1", 12, placement="compact",
                             grad_bytes=2e9)),
        Arrival(4.0, JobSpec("big", 40, placement="compact")),
        NodeFailure(9.0, 3),
        Departure(10.0, "t1"),
    ]


def _lifecycle_fifo():
    """The mixed scenario under the default (fifo, weight-1,
    constant-replan) configuration."""
    return Scenario(name="golden_lifecycle_fifo", topology=FABRIC64,
                    events=mixed_lifecycle_events(), horizon=16.0)


def _lifecycle_preempt():
    """Scheduler-policy scenario: a low-priority incumbent fills the fabric,
    a high-priority arrival preempts it, and the victim resumes with its
    progress intact once capacity frees."""
    events = [
        Arrival(0.0, JobSpec("low", 56, placement="compact", priority=0,
                             iters=60)),
        Arrival(2.0, JobSpec("high", 24, placement="compact", priority=5,
                             iters=20)),
        Arrival(3.0, JobSpec("fill", 6, placement="compact", priority=1)),
    ]
    return Scenario(name="golden_lifecycle_preempt", topology=FABRIC64,
                    events=events, policies=Policies(scheduler="preempt"),
                    horizon=16.0)


def _lifecycle_wfq():
    """Weighted sharing scenario: a heavy training tenant and a
    latency-sensitive inference fleet on the same up-links under
    fairness="wfq" with non-uniform weights and an SLO."""
    events = [
        # disjoint node sets sharing the leaf-1 uplink
        Arrival(0.0, JobSpec("train", 12, nodes=tuple(range(12)),
                             grad_bytes=4e9, weight=1.0)),
        Arrival(0.0, InferenceSpec("serve", 8, nodes=tuple(range(12, 20)),
                                   rate_rps=6.0, weight=4.0,
                                   slo_p99_s=0.5)),
    ]
    return Scenario(name="golden_lifecycle_wfq", topology=FABRIC64,
                    events=events, policies=Policies(fairness="wfq"),
                    horizon=12.0)


def _engine_maxmin():
    """Static-population FabricEngine under the default max-min fairness."""
    jobs = [JobSpec("a", 8, placement="scattered"),
            JobSpec("b", 8, placement="compact", grad_bytes=2e9),
            JobSpec("c", 8, placement="compact", algo="tree")]
    return Scenario(name="golden_engine_maxmin", topology=FABRIC64,
                    jobs=jobs, base_seed=1, iters=60, warmup=5)


FIXTURES = {
    "lifecycle_fifo": _lifecycle_fifo,
    "lifecycle_preempt": _lifecycle_preempt,
    "lifecycle_wfq": _lifecycle_wfq,
    "engine_maxmin": _engine_maxmin,
}


def _path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.json")


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_golden_replay_is_bit_identical(name):
    scenario = FIXTURES[name]()
    with open(_path(name)) as f:
        golden = json.load(f)
    assert scenario.run().fingerprint() == golden, (
        f"{name}: series diverged from the recorded golden fixture — the "
        f"determinism contract shifted. If the change is intended, "
        f"regenerate with `PYTHONPATH=src python "
        f"tests/test_golden_series.py` and review the diff.")


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_golden_scenarios_survive_json_round_trip(name):
    """The fixtures double as serialization regressions: a scenario
    rebuilt from its own JSON form replays the same fingerprint."""
    scenario = FIXTURES[name]()
    rebuilt = Scenario.from_json(scenario.to_json())
    with open(_path(name)) as f:
        golden = json.load(f)
    assert rebuilt.run().fingerprint() == golden


def regen(only=None):
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, build in sorted(FIXTURES.items()):
        if only and name not in only:
            continue
        with open(_path(name), "w") as f:
            json.dump(build().run().fingerprint(), f, indent=1,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote {_path(name)}")


if __name__ == "__main__":
    import sys
    regen(only=set(sys.argv[1:]) or None)
