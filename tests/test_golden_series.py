"""Golden determinism-regression fixtures.

PR 1/2 established a determinism contract: same seed + same event list =>
bit-identical step-time / latency series, across arrivals, blocked
admissions, failures, and re-placements. The property tests in
``test_lifecycle.py`` check *relations* (prefix equality, inertness); these
tests pin the *absolute* series: small engine / lifecycle scenarios are
serialized (float hex — bit-exact, no repr rounding) under
``tests/golden/`` and every run must replay them identically, so a future
refactor cannot silently shift the contract.

The ``lifecycle_fifo`` and ``engine_maxmin`` fixtures were generated from
the PR-2 code before weighted fair queuing and scheduler policies existed —
replaying them bit-exactly *is* the "``scheduler="fifo"``, all weights 1
reduces to PR-2" guarantee. ``lifecycle_preempt`` and ``lifecycle_wfq``
lock the new policies' output the same way for the next refactor.

Regenerate (only when a behavior change is intended and reviewed):

    PYTHONPATH=src python tests/test_golden_series.py
"""
import json
import os

import pytest

from repro.fabric import (Arrival, Departure, FabricEngine, InferenceSpec,
                          JobSpec, LifecycleEngine, NodeFailure, fat_tree)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _fabric():
    return fat_tree(64, nodes_per_leaf=8)


# ---------------------------------------------------------------------------
# scenarios: one builder per fixture, shared by the test and the regen entry
# ---------------------------------------------------------------------------


def mixed_lifecycle_events():
    """PR-2 shape: staggered arrivals, an open-loop inference co-tenant, a
    blocked arrival admitted on a departure, and a mid-run node failure.
    Shared with tests/test_scheduling.py so the scheduler-equivalence
    tests exercise exactly the scenario the golden fixture pins."""
    return [
        Arrival(0.0, JobSpec("t0", 12, placement="compact", algo="auto")),
        Arrival(2.0, InferenceSpec("serve", 4, rate_rps=8.0)),
        Arrival(3.0, JobSpec("t1", 12, placement="compact",
                             grad_bytes=2e9)),
        Arrival(4.0, JobSpec("big", 40, placement="compact")),
        NodeFailure(9.0, 3),
        Departure(10.0, "t1"),
    ]


def _lifecycle_fifo():
    """The mixed scenario under the default (fifo, weight-1,
    constant-replan) configuration."""
    return LifecycleEngine(_fabric(), mixed_lifecycle_events(),
                           base_seed=0).run(16.0)


def _lifecycle_preempt():
    """Scheduler-policy scenario: a low-priority incumbent fills the fabric,
    a high-priority arrival preempts it, and the victim resumes with its
    progress intact once capacity frees."""
    events = [
        Arrival(0.0, JobSpec("low", 56, placement="compact", priority=0,
                             iters=60)),
        Arrival(2.0, JobSpec("high", 24, placement="compact", priority=5,
                             iters=20)),
        Arrival(3.0, JobSpec("fill", 6, placement="compact", priority=1)),
    ]
    return LifecycleEngine(_fabric(), events, base_seed=0,
                           scheduler="preempt").run(16.0)


def _lifecycle_wfq():
    """Weighted sharing scenario: a heavy training tenant and a
    latency-sensitive inference fleet on the same up-links under
    fairness="wfq" with non-uniform weights and an SLO."""
    events = [
        # disjoint node sets sharing the leaf-1 uplink
        Arrival(0.0, JobSpec("train", 12, nodes=tuple(range(12)),
                             grad_bytes=4e9, weight=1.0)),
        Arrival(0.0, InferenceSpec("serve", 8, nodes=tuple(range(12, 20)),
                                   rate_rps=6.0, weight=4.0,
                                   slo_p99_s=0.5)),
    ]
    return LifecycleEngine(_fabric(), events, base_seed=0,
                           fairness="wfq").run(12.0)


def _engine_maxmin():
    """Static-population FabricEngine under the default max-min fairness."""
    jobs = [JobSpec("a", 8, placement="scattered"),
            JobSpec("b", 8, placement="compact", grad_bytes=2e9),
            JobSpec("c", 8, placement="compact", algo="tree")]
    return FabricEngine(_fabric(), jobs, base_seed=1).run(60, warmup=5)


# ---------------------------------------------------------------------------
# serialization: float hex is bit-exact across platforms and json round-trip
# ---------------------------------------------------------------------------


def _hex(xs):
    return [float(x).hex() for x in xs]


def _lifecycle_snapshot(res):
    snap = {"tenants": [], "log": [[float(t).hex(), kind]
                                   for t, kind, _ in res.log]}
    for t in res.tenants:
        entry = {"name": t.name, "kind": t.kind, "nodes": list(t.nodes),
                 "generation": t.generation}
        if t.kind == "training":
            entry["series"] = _hex(t.step_times)
            entry["iters_done"] = t.iters_done
        else:
            entry["series"] = _hex(t.latencies)
            entry["requests_done"] = t.requests_done
        snap["tenants"].append(entry)
    return snap


def _engine_snapshot(res):
    return {"jobs": [{"name": jr.name, "nodes": list(jr.nodes),
                      "algo": jr.algo, "series": _hex(jr.step_times)}
                     for jr in res.jobs],
            "link_bytes": {ln: float(b).hex()
                           for ln, b in sorted(res.link_bytes.items())}}


FIXTURES = {
    "lifecycle_fifo": (_lifecycle_fifo, _lifecycle_snapshot),
    "lifecycle_preempt": (_lifecycle_preempt, _lifecycle_snapshot),
    "lifecycle_wfq": (_lifecycle_wfq, _lifecycle_snapshot),
    "engine_maxmin": (_engine_maxmin, _engine_snapshot),
}


def _path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.json")


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_golden_replay_is_bit_identical(name):
    build, snapshot = FIXTURES[name]
    with open(_path(name)) as f:
        golden = json.load(f)
    assert snapshot(build()) == golden, (
        f"{name}: series diverged from the recorded golden fixture — the "
        f"determinism contract shifted. If the change is intended, "
        f"regenerate with `PYTHONPATH=src python "
        f"tests/test_golden_series.py` and review the diff.")


def regen(only=None):
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, (build, snapshot) in sorted(FIXTURES.items()):
        if only and name not in only:
            continue
        with open(_path(name), "w") as f:
            json.dump(snapshot(build()), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {_path(name)}")


if __name__ == "__main__":
    import sys
    regen(only=set(sys.argv[1:]) or None)
