"""Compiled collective schedules must be cost-identical to the per-call
models: exact per-link bytes, bottleneck link, and total_s, across
algorithms, topologies, and congestion (link_eff) states."""
import random

import pytest

from repro.fabric import (all_reduce, compile_schedule, fat_tree, tpu_pod)

TOPOS = {
    "fat_tree": lambda: fat_tree(32, nodes_per_leaf=8),
    "fat_tree_ragged": lambda: fat_tree(20, nodes_per_leaf=8),
    "tpu_pod": lambda: tpu_pod(2, ranks_per_pod=16),
}
ALGOS = ("ring", "tree", "hierarchical")


def _eff_states(topo, seed=0):
    """None (uncongested) plus several random shared-tier congestion maps."""
    rng = random.Random(seed)
    shared = [ln for ln, l in topo.links.items() if l.shared]
    states = [None, {}]
    for _ in range(4):
        states.append({ln: 0.05 + 0.9 * rng.random() for ln in shared})
    # single-link jams move the bottleneck around
    states.extend({ln: 0.02} for ln in shared[:3])
    return states


@pytest.mark.parametrize("topo_name", sorted(TOPOS))
@pytest.mark.parametrize("algo", ALGOS)
def test_compiled_cost_equals_per_call_cost(topo_name, algo):
    topo = TOPOS[topo_name]()
    ranks = list(range(topo.n_ranks))
    sched = compile_schedule(topo, ranks, 1.3e9, algo=algo)
    for eff in _eff_states(topo, seed=hash((topo_name, algo)) % 1000):
        legacy = all_reduce(topo, ranks, 1.3e9, algo=algo, link_eff=eff)
        comp = sched.cost(eff)
        assert comp.total_s == legacy.total_s
        assert comp.steps == legacy.steps
        assert comp.bottleneck_link == legacy.bottleneck_link
        assert comp.per_link_bytes == legacy.per_link_bytes
        # scalar fast path agrees with the full evaluation
        assert sched.total_s(eff) == legacy.total_s


@pytest.mark.parametrize("algo", ALGOS)
def test_compiled_subset_ranks(algo):
    """Schedules over non-contiguous rank subsets (engine placements)."""
    topo = fat_tree(32, nodes_per_leaf=8)
    ranks = [0, 3, 8, 9, 17, 21, 25, 30]
    sched = compile_schedule(topo, ranks, 7e8, algo=algo)
    for eff in _eff_states(topo, seed=7):
        legacy = all_reduce(topo, ranks, 7e8, algo=algo, link_eff=eff)
        assert sched.cost(eff).total_s == legacy.total_s
        assert sched.cost(eff).per_link_bytes == legacy.per_link_bytes


def test_compiled_accumulate_matches_per_iter_adds():
    """accumulate_bytes replicates the seed loop's per-iteration dict adds."""
    topo = fat_tree(16, nodes_per_leaf=8)
    ranks = list(range(16))
    sched = compile_schedule(topo, ranks, 1.1e9, algo="ring")
    want, got = {}, {}
    for _ in range(100):
        cost = all_reduce(topo, ranks, 1.1e9, algo="ring")
        for ln, b in cost.per_link_bytes.items():
            want[ln] = want.get(ln, 0.0) + b
        sched.accumulate_bytes(None, got)
    assert got == want


def test_compiled_trivial_and_unknown():
    topo = fat_tree(8)
    zero = compile_schedule(topo, [0], 1e9, algo="ring")
    assert zero.total_s() == 0.0 and zero.cost().per_link_bytes == {}
    with pytest.raises(KeyError):
        compile_schedule(topo, [0, 1], 1e9, algo="nope")


def test_compiled_hierarchical_group_fallback():
    """n <= group degenerates to a plain ring, like the per-call path."""
    topo = fat_tree(8, nodes_per_leaf=8)
    ranks = list(range(4))
    sched = compile_schedule(topo, ranks, 1e9, algo="hierarchical", group=8)
    legacy = all_reduce(topo, ranks, 1e9, algo="hierarchical", group=8)
    assert sched.cost(None).total_s == legacy.total_s


# ---------------------------------------------------------------------------
# algo auto-selection from compiled-schedule byte exposure
# ---------------------------------------------------------------------------


def test_select_algo_is_optimal_over_candidates():
    from repro.fabric import select_algo
    from repro.fabric.placement import group_size
    for make in TOPOS.values():
        topo = make()
        g = group_size(topo)          # the group select_algo resolves to
        for nodes in ([0, 1, 2, 3], list(range(12)),
                      list(range(0, topo.n_ranks, 2))[:10]):
            algo, sched = select_algo(topo, nodes, 1.1e9)
            assert algo in ALGOS
            t = sched.total_s(None)
            for cand in ALGOS:
                other = compile_schedule(topo, nodes, 1.1e9, algo=cand,
                                         group=g)
                assert t <= other.total_s(None) + 1e-12


def test_select_algo_weight_shifts_the_choice():
    """WFQ weight reaches auto selection: a weight-1 tenant on a scattered
    placement keeps traffic off the shared tier (hierarchical); a heavy
    tenant keeps most of a contended link anyway, discounts the shared
    exposure, and takes the uncongested-fastest ring. weight=1.0 must be
    the PR-2 selection exactly."""
    from repro.fabric import select_algo
    from repro.fabric.placement import place
    topo = fat_tree(64, nodes_per_leaf=8)
    nodes = place("scattered", topo, 12)
    unweighted = select_algo(topo, nodes, 1.1e9)
    assert unweighted[0] == "hierarchical"
    assert select_algo(topo, nodes, 1.1e9, weight=1.0)[0] == unweighted[0]
    # light tenants agree with (or exceed) the shared-tier aversion...
    assert select_algo(topo, nodes, 1.1e9, weight=0.25)[0] \
        == "hierarchical"
    # ...heavy tenants flip to raw speed
    assert select_algo(topo, nodes, 1.1e9, weight=8.0)[0] == "ring"


def test_select_algo_deterministic():
    from repro.fabric import select_algo
    topo = fat_tree(32, nodes_per_leaf=8)
    picks = {select_algo(topo, list(range(12)), 1.1e9)[0]
             for _ in range(3)}
    assert len(picks) == 1
