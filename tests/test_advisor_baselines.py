"""Versioned attribution baselines for the library failure modes.

The attribution invariants (``tests/test_advisor.py``) check structure;
these baselines pin the *numbers*: each failure-mode library scenario's
full per-tenant bucket decomposition — measured/floor/sync/contention/
locality/residual, mean and p99, plus the analytic factors — persisted
bit-exactly (float hex) under ``tests/baselines/advisor/``. A change to
the engine, the congestion model, or the attribution arithmetic that
moves any bucket by one ulp fails here with a per-path diff.

Regenerate (only when a behavior change is intended and reviewed):

    make baselines            # regenerates these alongside the others
    make baselines-check      # CI drift gate
"""
import json
import os
import sys

import pytest

from repro.fabric.advisor import attribute
from repro.fabric.scenario import library
from test_baselines import _hexify, diff_paths

ADVISOR_BASELINE_DIR = os.path.join(os.path.dirname(__file__),
                                    "baselines", "advisor")
BASELINE_VERSION = 1

# attribution is pinned for the paper's named failure modes plus the
# mixed training/inference scenario (the coarse inference path)
PINNED = ("synchronization_amplification", "topology_contention",
          "locality_variance", "noisy_neighbor_inference")

REGEN_HINT = ("if the change is intended and reviewed, regenerate with "
              "`make baselines` and commit the diff under "
              "tests/baselines/advisor/")


def snapshot(name: str) -> dict:
    result = library.build(name).run()
    return {"version": BASELINE_VERSION, "scenario": name,
            "attribution": _hexify(attribute(result).to_dict())}


def baseline_path(name: str) -> str:
    return os.path.join(ADVISOR_BASELINE_DIR, f"{name}.json")


def check(name: str) -> list:
    path = baseline_path(name)
    if not os.path.exists(path):
        return [f"$: no advisor baseline recorded at {path}"]
    with open(path) as f:
        expected = json.load(f)
    return diff_paths(expected, snapshot(name))


@pytest.mark.parametrize("name", sorted(PINNED))
def test_attribution_matches_baseline(name):
    drift = check(name)
    assert not drift, (
        f"{name}: attribution drifted from tests/baselines/advisor/"
        f"{name}.json — {REGEN_HINT}\n  " + "\n  ".join(drift))


def test_every_advisor_baseline_is_pinned():
    on_disk = {f[:-5] for f in os.listdir(ADVISOR_BASELINE_DIR)
               if f.endswith(".json")}
    assert on_disk == set(PINNED), (
        f"advisor baseline files {sorted(on_disk)} != pinned set "
        f"{sorted(PINNED)} — {REGEN_HINT}")


def test_baselines_pin_the_dominant_buckets():
    """The acceptance matrix is readable straight off the committed
    files (no simulation): each failure mode's recorded dominant bucket
    matches its name."""
    expect = {"synchronization_amplification": ("bsp", "synchronization_s"),
              "topology_contention": ("primary", "contention_s"),
              "locality_variance": ("job", "locality_s")}
    for name, (tenant, bucket) in expect.items():
        with open(baseline_path(name)) as f:
            mean = json.load(f)["attribution"]["tenants"][tenant]["mean"]
        vals = {k: float.fromhex(v) for k, v in mean.items()
                if k in ("synchronization_s", "contention_s",
                         "locality_s")}
        assert max(vals, key=vals.get) == bucket, (name, vals)


# ---------------------------------------------------------------------------
# regen / check entry points (driven by make baselines / baselines-check)
# ---------------------------------------------------------------------------


def regen(only=None) -> None:
    os.makedirs(ADVISOR_BASELINE_DIR, exist_ok=True)
    for stale in sorted(os.listdir(ADVISOR_BASELINE_DIR)):
        if stale.endswith(".json") and stale[:-5] not in PINNED:
            os.remove(os.path.join(ADVISOR_BASELINE_DIR, stale))
            print(f"removed stale {stale}")
    for name in sorted(PINNED):
        if only and name not in only:
            continue
        with open(baseline_path(name), "w") as f:
            json.dump(snapshot(name), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {baseline_path(name)}")


def run_check() -> int:
    bad = 0
    for name in sorted(PINNED):
        drift = check(name)
        if drift:
            bad += 1
            print(f"DRIFT {name}:")
            for d in drift:
                print(f"  {d}")
        else:
            print(f"ok    {name}")
    if bad:
        print(f"\n{bad} attribution(s) drifted from "
              f"tests/baselines/advisor/ — {REGEN_HINT}")
    return 1 if bad else 0


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--check" in argv:
        sys.exit(run_check())
    regen(only=set(argv) or None)
