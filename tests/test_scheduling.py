"""Scheduler-policy matrix for the lifecycle engine's blocked-arrival
queue: fifo stays bit-identical to the PR-2 behavior, backfill never
delays an already-queued higher-priority tenant, preemption victims resume
with exactly their remaining work, and the checkpoint-restore cost model
replaces the constant replan delay when asked to."""
import math

import pytest

from repro.fabric import (Arrival, Departure, InferenceSpec, JobSpec,
                          LifecycleEngine, NodeFailure, fat_tree)
from repro.ft import RestoreCostModel
from test_golden_series import mixed_lifecycle_events

HORIZON = 20.0


def _fabric():
    return fat_tree(64, nodes_per_leaf=8)


def _run(events, until=HORIZON, **kw):
    return LifecycleEngine(_fabric(), events, base_seed=0, **kw).run(until)


def _series(res):
    out = {}
    for t in res.tenants:
        out[t.name] = t.step_times if t.kind == "training" else t.latencies
    return out


# the exact scenario the lifecycle_fifo golden fixture pins
_mixed_scenario = mixed_lifecycle_events


# ---------------------------------------------------------------------------
# fifo: the explicit name for today's behavior
# ---------------------------------------------------------------------------


def test_explicit_fifo_is_bit_identical_to_default():
    assert _series(_run(_mixed_scenario(), scheduler="fifo")) \
        == _series(_run(_mixed_scenario()))


def test_backfill_with_uniform_priorities_matches_fifo_series():
    """Stable priority sort + same placement seeds: when nobody outranks
    anybody, backfill admits in arrival order and every series is
    bit-identical to fifo (the log may carry extra retry records)."""
    assert _series(_run(_mixed_scenario(), scheduler="backfill")) \
        == _series(_run(_mixed_scenario(), scheduler="fifo"))


# ---------------------------------------------------------------------------
# backfill: priority-ordered drain
# ---------------------------------------------------------------------------


def _contended_queue(with_small=True):
    events = [
        Arrival(0.0, JobSpec("incumbent", 60, placement="compact")),
        # small arrives first but carries no priority...
        Arrival(1.0, JobSpec("small", 20, placement="compact", priority=0)),
        # ...the big waiter outranks it
        Arrival(2.0, JobSpec("urgent", 50, placement="compact",
                             priority=5)),
        Departure(8.0, "incumbent"),
    ]
    if not with_small:
        del events[1]
    return events


def test_backfill_admits_higher_priority_first():
    """fifo hands the freed fabric to the first-come small tenant and
    starves the urgent one; backfill admits the urgent tenant first."""
    fifo = _run(_contended_queue(), scheduler="fifo")
    assert len(fifo.tenant("small").step_times) > 0
    with pytest.raises(KeyError):
        fifo.tenant("urgent")                        # never fit again

    back = _run(_contended_queue(), scheduler="backfill")
    urgent = back.tenant("urgent")
    assert urgent.arrived_t is not None and urgent.arrived_t >= 8.0
    assert len(urgent.step_times) > 0
    with pytest.raises(KeyError):
        back.tenant("small")                         # 14 free < 20


def test_backfill_never_delays_queued_higher_priority_tenant():
    """The satellite property: adding a low-priority co-waiter must not
    move the higher-priority tenant's admission time at all."""
    with_small = _run(_contended_queue(), scheduler="backfill")
    without = _run(_contended_queue(with_small=False),
                   scheduler="backfill")
    assert with_small.tenant("urgent").arrived_t \
        == without.tenant("urgent").arrived_t


def test_backfill_fills_leftover_capacity():
    """A small low-priority tenant backfills capacity the high-priority
    waiter cannot use — in the same drain pass."""
    events = [
        Arrival(0.0, JobSpec("incumbent", 60, placement="compact")),
        Arrival(1.0, JobSpec("small", 8, placement="compact", priority=0)),
        Arrival(2.0, JobSpec("urgent", 50, placement="compact",
                             priority=5)),
        Departure(8.0, "incumbent"),
    ]
    res = _run(events, scheduler="backfill")
    urgent, small = res.tenant("urgent"), res.tenant("small")
    assert urgent.arrived_t is not None and small.arrived_t is not None
    # both admitted at the same freed-capacity instant, urgent first
    assert small.arrived_t == urgent.arrived_t
    assert len(urgent.step_times) > 0 and len(small.step_times) > 0


# ---------------------------------------------------------------------------
# preempt: eviction with progress intact
# ---------------------------------------------------------------------------


def test_preempt_evicts_lowest_priority_victim():
    events = [
        Arrival(0.0, JobSpec("low", 30, placement="compact", priority=0)),
        Arrival(0.5, JobSpec("mid", 26, placement="compact", priority=2)),
        Arrival(4.0, JobSpec("vip", 24, placement="compact", priority=9,
                             iters=10)),
    ]
    res = _run(events, scheduler="preempt")
    vip, low, mid = res.tenant("vip"), res.tenant("low"), res.tenant("mid")
    # the vip was admitted at its arrival (not at some later departure),
    # by evicting the *lowest* priority tenant only
    assert 4.0 <= vip.arrived_t < 5.0
    assert len(vip.step_times) == 10
    assert [e.kind for e in low.recovery.events][:1] == ["preempted"]
    assert all(e.kind != "preempted" for e in mid.recovery.events)
    preempted = [d for _, k, d in res.log if k == "preempted"]
    assert len(preempted) == 1 and "low" in preempted[0]


def test_preempt_victim_resumes_with_identical_remaining_work():
    """The victim's iteration budget is conserved across the eviction: it
    finishes exactly spec.iters steps, with the stall visible in-series."""
    events = [
        Arrival(0.0, JobSpec("victim", 40, placement="compact", priority=0,
                             iters=40)),
        Arrival(3.0, JobSpec("vip", 48, placement="compact", priority=5,
                             iters=8)),
    ]
    res = _run(events, until=30.0, scheduler="preempt")
    victim, vip = res.tenant("victim"), res.tenant("vip")
    assert len(vip.step_times) == 8
    kinds = [e.kind for e in victim.recovery.events]
    assert kinds == ["preempted", "resume"]
    # identical remaining work: exactly the full budget in total, no step
    # lost and none repeated
    assert victim.iters_done == 40
    assert len(victim.step_times) == 40
    assert victim.generation == 2 and len(victim.placements) == 2
    assert all(s > 0.0 and math.isfinite(s) for s in victim.step_times)
    # the preemption stall (vip's whole run + replan) dominates the series
    assert max(victim.step_times) > 3 * min(victim.step_times)


def test_preempt_never_evicts_inference_or_higher_priority():
    events = [
        Arrival(0.0, InferenceSpec("serve", 40, rate_rps=6.0, priority=0)),
        Arrival(0.0, JobSpec("guard", 20, placement="compact", priority=7)),
        Arrival(3.0, JobSpec("bully", 10, placement="compact", priority=3)),
    ]
    res = _run(events, scheduler="preempt")
    assert not [1 for _, k, _ in res.log if k == "preempted"]
    with pytest.raises(KeyError):
        res.tenant("bully")
    assert any(k == "blocked" and "bully" in d for _, k, d in res.log)


def test_preempt_no_gratuitous_eviction():
    """If evicting every eligible victim still cannot host the arrival,
    nobody is evicted."""
    events = [
        Arrival(0.0, JobSpec("small_low", 10, placement="compact",
                             priority=0)),
        Arrival(1.0, JobSpec("guard", 44, placement="compact", priority=8)),
        # needs 60: free 10 + evictable 10 = 20 < 60 -> no eviction
        Arrival(3.0, JobSpec("huge", 60, placement="compact", priority=5)),
    ]
    res = _run(events, scheduler="preempt")
    assert not [1 for _, k, _ in res.log if k == "preempted"]
    low = res.tenant("small_low")
    assert all(e.kind != "preempted" for e in low.recovery.events)
    assert len(low.step_times) > 0


def test_preempted_pinned_tenant_resumes_on_its_pinned_nodes():
    """A full-size tenant pinned to explicit nodes must come back on
    exactly those nodes after a preemption, not wherever its placement
    policy lands — the pin encodes the scenario's premise."""
    events = [
        Arrival(0.0, JobSpec("pinned", 40, nodes=tuple(range(40)),
                             priority=0, iters=40)),
        Arrival(3.0, JobSpec("vip", 50, placement="compact", priority=5,
                             iters=8)),
    ]
    res = _run(events, until=30.0, scheduler="preempt")
    pinned = res.tenant("pinned")
    assert [e.kind for e in pinned.recovery.events] == ["preempted",
                                                       "resume"]
    assert tuple(pinned.nodes) == tuple(range(40))
    assert pinned.iters_done == 40


def test_slo_attainment_is_zero_for_a_starved_fleet():
    from repro.fabric.workloads import InferenceTenant
    starved = InferenceTenant(InferenceSpec("s", 4, slo_p99_s=0.1), seed=0)
    assert starved.slo_attainment == 0.0
    no_slo = InferenceTenant(InferenceSpec("s", 4), seed=0)
    assert no_slo.slo_attainment == 1.0


def test_preempted_tenant_can_depart_while_queued():
    events = [
        Arrival(0.0, JobSpec("victim", 56, placement="compact", priority=0)),
        Arrival(2.0, JobSpec("vip", 48, placement="compact", priority=5)),
        Departure(6.0, "victim"),
    ]
    res = _run(events, scheduler="preempt")
    victim = res.tenant("victim")
    assert victim.departed_t == 6.0
    assert [e.kind for e in victim.recovery.events] == ["preempted"]
    # its pre-eviction progress is still reported
    assert len(victim.step_times) > 0


# ---------------------------------------------------------------------------
# replan delay: constant vs checkpoint-restore cost model
# ---------------------------------------------------------------------------


def test_restore_cost_model_delay():
    m = RestoreCostModel(read_bw_Bps=2e9, overhead_s=0.1)
    assert m.delay_s(0.0) == pytest.approx(0.1)
    assert m.delay_s(4e9) == pytest.approx(2.1)
    with pytest.raises(ValueError):
        m.delay_s(-1.0)
    # defaults reproduce the PR-2 constant for the default 1.1 GB job
    assert RestoreCostModel().delay_s(1.1e9) == pytest.approx(0.525)


def _recovery_gap(**kw):
    res = _run([Arrival(0.0, JobSpec("job", 12, placement="compact",
                                     grad_bytes=2e9)),
                NodeFailure(6.0, 2)], until=25.0, **kw)
    job = res.tenant("job")
    detected = [t for t, k, _ in res.log if k == "detected"][0]
    return job.placements[1][0] - detected


def test_replan_delay_constant_is_the_default():
    assert _recovery_gap() == pytest.approx(0.5)
    assert _recovery_gap(replan_delay_s=1.25) == pytest.approx(1.25)


def test_replan_delay_from_restore_cost_model():
    """replan_delay_s=None derives the stall from the tenant's parameter
    bytes and the store's read bandwidth."""
    gap = _recovery_gap(replan_delay_s=None,
                        restore_cost=RestoreCostModel(read_bw_Bps=1e9,
                                                      overhead_s=0.2))
    assert gap == pytest.approx(0.2 + 2e9 / 1e9)  # grad_bytes = 2e9
    # explicit restore_cost wins even without replan_delay_s=None
    gap = _recovery_gap(restore_cost=RestoreCostModel(read_bw_Bps=4e9,
                                                      overhead_s=0.0))
    assert gap == pytest.approx(0.5)              # 2e9 / 4e9
    # param_bytes overrides the grad-size estimate
    res = _run([Arrival(0.0, JobSpec("job", 12, placement="compact",
                                     grad_bytes=2e9, param_bytes=8e9)),
                NodeFailure(6.0, 2)], until=25.0, replan_delay_s=None,
               restore_cost=RestoreCostModel(read_bw_Bps=1e9,
                                             overhead_s=0.0))
    job = res.tenant("job")
    detected = [t for t, k, _ in res.log if k == "detected"][0]
    assert job.placements[1][0] - detected == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# anti-thrash preemption budget (min_runtime_s)
# ---------------------------------------------------------------------------


def _thrash_events(burst2_t=6.0):
    """Two high-priority bursts in quick succession against one
    low-priority incumbent: without a budget the incumbent is evicted
    twice (it resumes at ~5.6 when burst1 departs; burst2 at t=6 catches
    it ~0.4 s into its second run)."""
    return [
        Arrival(0.0, JobSpec("victim", 56, placement="compact",
                             priority=0, iters=200)),
        Arrival(2.0, JobSpec("burst1", 48, placement="compact",
                             priority=5, iters=5)),
        Arrival(burst2_t, JobSpec("burst2", 48, placement="compact",
                                  priority=5, iters=5)),
    ]


def _evictions(res, name):
    return [t for t, k, d in res.log if k == "preempted" and name in d]


def test_preempt_matrix_no_repeat_eviction_inside_the_window():
    """The satellite policy-matrix: under the budget-less preempt policy
    the victim thrashes (two evictions inside the window); with
    min_runtime_s covering the burst spacing the second eviction is
    blocked; fifo/backfill never evict at all."""
    from repro.fabric.scheduling import PreemptScheduler
    horizon = 30.0
    window = 10.0

    thrash = _run(_thrash_events(), until=horizon, scheduler="preempt")
    evs = _evictions(thrash, "victim")
    assert len(evs) == 2 and evs[1] - evs[0] < window

    guarded = _run(_thrash_events(), until=horizon,
                   scheduler=PreemptScheduler(min_runtime_s=window))
    evs = _evictions(guarded, "victim")
    assert len(evs) == 1
    # the window only defers, it does not outlaw: burst2 blocks instead
    assert any(k == "blocked" and "burst2" in d
               for _, k, d in guarded.log)

    for policy in ("fifo", "backfill"):
        res = _run(_thrash_events(), until=horizon, scheduler=policy)
        assert not [1 for _, k, _ in res.log if k == "preempted"]


def test_min_runtime_counts_runtime_not_time_since_eviction():
    """Time spent queued must not burn the budget: the victim is evicted
    at ~2.3 and only resumes at ~5.6 when burst1 departs, so by burst2's
    t=6 arrival more than the 3 s window has passed *since the eviction*
    — but the victim has run for only ~0.4 s. The window is armed at the
    resume, so the re-eviction is still blocked."""
    from repro.fabric.scheduling import PreemptScheduler
    res = _run(_thrash_events(), until=30.0,
               scheduler=PreemptScheduler(min_runtime_s=3.0))
    evs = _evictions(res, "victim")
    resume_t = [t for t, k, d in res.log if k == "resumed"
                and "victim" in d][0]
    assert len(evs) == 1
    assert 6.0 - evs[0] > 3.0           # eviction-clock would have allowed
    assert 6.0 - resume_t < 3.0         # runtime-clock correctly blocks


def test_min_runtime_window_allows_reeviction_after_expiry():
    """Evictions separated by more than the window of actual runtime are
    both allowed — the budget rate-limits churn, it does not grant
    immunity."""
    from repro.fabric.scheduling import PreemptScheduler
    res = _run(_thrash_events(burst2_t=9.0), until=30.0,
               scheduler=PreemptScheduler(min_runtime_s=3.0))
    evs = _evictions(res, "victim")
    resumes = [t for t, k, d in res.log if k == "resumed"
               and "victim" in d]
    assert len(evs) == 2
    # the second eviction came after >= 3 s of runtime since the resume
    assert evs[1] - resumes[0] >= 3.0


def test_zero_budget_is_bit_identical_to_pr3_preempt():
    from repro.fabric.scheduling import PreemptScheduler
    a = _run(_thrash_events(), until=30.0, scheduler="preempt")
    b = _run(_thrash_events(), until=30.0,
             scheduler=PreemptScheduler(min_runtime_s=0.0))
    assert _series(a) == _series(b)
    assert [e[:2] for e in a.log] == [e[:2] for e in b.log]


def test_preempt_scheduler_rejects_negative_budget():
    from repro.fabric.scheduling import PreemptScheduler, make_scheduler
    with pytest.raises(ValueError):
        PreemptScheduler(min_runtime_s=-1.0)
    with pytest.raises(TypeError):
        make_scheduler(PreemptScheduler(), min_runtime_s=1.0)


# ---------------------------------------------------------------------------
# checkpoint-aware preemption resume (JobSpec.ckpt_every)
# ---------------------------------------------------------------------------


def _preempt_once_events(**victim_kw):
    victim_kw.setdefault("iters", 40)
    return [
        Arrival(0.0, JobSpec("victim", 40, placement="compact", priority=0,
                             **victim_kw)),
        Arrival(3.0, JobSpec("vip", 48, placement="compact", priority=5,
                             iters=8)),
    ]


def test_cadence_helpers():
    from repro.ckpt import CheckpointCadence, latest_restorable_step
    assert latest_restorable_step(13, 4) == 12
    assert latest_restorable_step(12, 4) == 12
    assert latest_restorable_step(3, 1) == 3
    assert latest_restorable_step(0, 7) == 0
    with pytest.raises(ValueError):
        latest_restorable_step(5, 0)
    with pytest.raises(ValueError):
        latest_restorable_step(-1, 2)
    cad = CheckpointCadence(every=4)
    assert cad.restore_step(13) == 12 and cad.lost_steps(13) == 1
    with pytest.raises(ValueError):
        CheckpointCadence(every=0)
    with pytest.raises(ValueError):
        JobSpec("j", 4, ckpt_every=0)


def test_ckpt_resume_continues_the_stream_instead_of_restarting():
    """With per-step checkpoints the victim resumes the *original*
    compute stream at its eviction step: the pre-eviction series is
    bit-identical to the restart-mode run (same stream, same contention)
    and the post-resume series diverges (continuation vs fresh epoch),
    with no step lost and none repeated."""
    restart = _run(_preempt_once_events(), until=40.0,
                   scheduler="preempt").tenant("victim")
    ckpt = _run(_preempt_once_events(ckpt_every=1), until=40.0,
                scheduler="preempt").tenant("victim")
    k = restart.recovery.events[0].step
    assert ckpt.recovery.events[0].step == k
    assert 0 < k < 40
    # identical prefix up to the eviction...
    assert ckpt.step_times[:k] == restart.step_times[:k]
    # ...different draws after the resume (restart reseeds the epoch
    # stream; checkpoint-aware resume continues the original one)
    assert ckpt.step_times[k:] != restart.step_times[k:]
    # budget conserved exactly under cadence 1: nothing lost or repeated
    assert ckpt.iters_done == 40 and len(ckpt.step_times) == 40


def test_ckpt_cadence_replays_exactly_the_lost_work():
    """A coarser cadence rewinds to the newest checkpoint: the steps
    since are re-executed, so the series carries budget + lost entries
    while the iteration budget itself is still met."""
    res = _run(_preempt_once_events(ckpt_every=4), until=40.0,
               scheduler="preempt")
    victim = res.tenant("victim")
    k = victim.recovery.events[0].step
    lost = k - (k // 4) * 4
    assert lost > 0, "eviction step must not sit on the cadence for " \
        "this fixture to bite; tune vip arrival if it does"
    assert victim.iters_done == 40
    assert len(victim.step_times) == 40 + lost
    assert [e.kind for e in victim.recovery.events] == ["preempted",
                                                       "resume"]
    # the resume record points at the checkpoint step, not the eviction
    assert victim.recovery.events[1].step == k - lost


def test_ckpt_resume_default_is_pr3_restart_bit_for_bit():
    """ckpt_every=None keeps the golden behavior: the explicit regression
    that adding the field changed nothing by default."""
    a = _run(_preempt_once_events(), until=40.0, scheduler="preempt")
    b = _run(_preempt_once_events(ckpt_every=None), until=40.0,
             scheduler="preempt")
    assert _series(a) == _series(b)


# ---------------------------------------------------------------------------
# slow-horizon WFQ scenario
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_slow_wfq_weight_sweep_trades_inference_tail_latency():
    """Full-horizon weight sweep on a shared 64-node fabric: raising the
    inference fleet's WFQ weight must improve its p99 latency and SLO
    attainment monotonically enough to separate the sweep's endpoints."""
    def p99(w):
        events = [
            # disjoint node sets sharing the leaf-1 uplink
            Arrival(0.0, JobSpec("train", 24,
                                 nodes=tuple(range(12))
                                 + tuple(range(24, 36)),
                                 grad_bytes=6e9)),
            Arrival(0.0, InferenceSpec("serve", 8,
                                       nodes=tuple(range(12, 20)),
                                       rate_rps=10.0, weight=w,
                                       slo_p99_s=0.4)),
        ]
        serve = _run(events, until=80.0, fairness="wfq") \
            .tenant("serve")
        return serve.latency_quantile(0.99), serve.slo_attainment

    lo_lat, lo_att = p99(0.25)
    hi_lat, hi_att = p99(8.0)
    assert hi_lat < lo_lat
    assert hi_att >= lo_att
