"""Fabric substrate tests: topology, collective cost models, congestion,
simulator, and the paper-reproduction properties."""
import math

import pytest

from repro.core import diagnose
from repro.fabric import (CongestionConfig, CongestionModel, SimConfig,
                          StragglerConfig, all_reduce, fat_tree,
                          hierarchical_all_reduce, ring_all_reduce, simulate,
                          tpu_pod, tree_all_reduce)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------


def test_fat_tree_hop_links():
    topo = fat_tree(16, nodes_per_leaf=8)
    assert topo.hop_links(0, 1) == ["leaf0"]
    assert topo.hop_links(7, 8) == ["up0", "spine", "up1"]
    assert topo.n_ranks == 16


def test_tpu_pod_hop_links():
    topo = tpu_pod(2, ranks_per_pod=4)
    assert topo.hop_links(0, 1) == ["ici0"]
    assert topo.hop_links(3, 4) == ["dcn0", "dcn_core", "dcn1"]


# ---------------------------------------------------------------------------
# collective cost models
# ---------------------------------------------------------------------------


def test_ring_all_reduce_scales_with_bytes():
    topo = fat_tree(8)
    c1 = ring_all_reduce(topo, range(8), 1e9)
    c2 = ring_all_reduce(topo, range(8), 2e9)
    assert c2.total_s == pytest.approx(2 * c1.total_s, rel=0.01)
    assert c1.steps == 2 * 7


def test_ring_all_reduce_approaches_bandwidth_bound():
    """Within one non-blocking leaf, ring time -> 2*bytes/port_bw."""
    nbytes = 1e9
    topo = fat_tree(8, leaf_bw=50.0)
    c = ring_all_reduce(topo, range(8), nbytes)
    bound = 2 * (8 - 1) / 8 * nbytes / 50e9
    assert c.total_s == pytest.approx(bound, rel=0.01)


def test_tree_beats_ring_latency_for_tiny_payloads():
    topo = fat_tree(64)
    tiny = 1e3
    ring = ring_all_reduce(topo, range(64), tiny)
    tree = tree_all_reduce(topo, range(64), tiny)
    assert tree.total_s < ring.total_s       # 2log2(64) << 2*63 latencies


def test_hierarchical_reduces_shared_tier_bytes():
    topo = fat_tree(32, nodes_per_leaf=8)
    nbytes = 1e9
    ring = ring_all_reduce(topo, range(32), nbytes)
    hier = hierarchical_all_reduce(topo, range(32), nbytes, group=8)
    ring_shared = sum(b for ln, b in ring.per_link_bytes.items()
                      if topo.link(ln).shared)
    hier_shared = sum(b for ln, b in hier.per_link_bytes.items()
                      if topo.link(ln).shared)
    assert hier_shared < ring_shared


def test_congested_link_slows_collective():
    topo = fat_tree(16, nodes_per_leaf=8)
    free = all_reduce(topo, range(16), 1e9)
    jam = all_reduce(topo, range(16), 1e9,
                     link_eff={"up0": 0.05, "up1": 0.05, "spine": 0.05})
    assert jam.total_s > free.total_s


# ---------------------------------------------------------------------------
# congestion dynamics
# ---------------------------------------------------------------------------


def test_congestion_ar1_stays_bounded():
    topo = fat_tree(32)
    cm = CongestionModel(CongestionConfig(u_sigma=0.5, u_max=0.9), topo)
    for _ in range(500):
        cm.advance()
        for u in cm.u.values():
            assert 0.0 <= u <= 0.9


def test_congestion_kick_persists_and_decays():
    topo = fat_tree(32)
    cm = CongestionModel(CongestionConfig(u_mean=0.1, u_sigma=0.0,
                                          u_rho=0.9, k_kick=0.2), topo)
    base = dict(cm.u)
    cm.kick(2.0)
    kicked = dict(cm.u)
    assert all(kicked[k] > base[k] for k in base)
    for _ in range(100):
        cm.advance()
    assert all(abs(cm.u[k] - 0.1) < 0.05 for k in base)


def test_burst_derates_only_shared_links():
    topo = fat_tree(16)
    cm = CongestionModel(CongestionConfig(), topo)
    eff = cm.link_eff(skew_ratio=2.0, spanning_groups=2)
    assert set(eff) == {n for n, l in topo.links.items() if l.shared}
    assert all(v < 1.0 for v in eff.values())


# ---------------------------------------------------------------------------
# simulator: paper-reproduction properties
#
# The full-fidelity (SimConfig.paper) runs carry the quantitative Table-1
# comparison and are marked slow; the fast-preset section below keeps the
# qualitative signatures in default tier-1.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paper_runs():
    out = {}
    for n in (4, 16, 64):
        out[n] = {
            "base": simulate(SimConfig.paper(n, coordination=False)),
            "coord": simulate(SimConfig.paper(n, coordination=True)),
        }
    return out


@pytest.mark.slow
def test_scaling_efficiency_decreases(paper_runs):
    eff = {n: r["base"].throughput / n for n, r in paper_runs.items()}
    assert eff[16] < eff[4]
    assert eff[64] < eff[16]


@pytest.mark.slow
def test_instability_grows_with_scale(paper_runs):
    assert paper_runs[64]["base"].cv > paper_runs[4]["base"].cv


@pytest.mark.slow
def test_coordination_cuts_cv_at_scale(paper_runs):
    base = paper_runs[64]["base"].cv
    coord = paper_runs[64]["coord"].cv
    assert coord < 0.75 * base


@pytest.mark.slow
def test_coordination_improves_throughput_at_scale_only(paper_runs):
    d64 = paper_runs[64]["coord"].throughput / \
        paper_runs[64]["base"].throughput - 1
    d4 = paper_runs[4]["coord"].throughput / \
        paper_runs[4]["base"].throughput - 1
    assert d64 > 0.05                  # paper: +11% at 64 nodes
    assert abs(d4) < 0.02              # paper: -0.6% at 4 nodes


@pytest.mark.slow
def test_throughput_matches_paper_table1(paper_runs):
    targets = {4: 1024, 16: 3600, 64: 8200}
    for n, tgt in targets.items():
        thr = paper_runs[n]["base"].throughput
        assert abs(thr / tgt - 1) < 0.10, (n, thr, tgt)


@pytest.mark.slow
def test_simulator_records_feed_diagnostics(paper_runs):
    res = paper_runs[64]["base"]
    rep = diagnose(res.per_rank_records())
    assert rep.n_ranks == 64
    assert rep.dominant in ("sync_amplification", "fabric_contention",
                            "locality_variance")
    # with congestion + stragglers at 64 nodes, waits must be significant
    scores = {s.mode: s.score for s in rep.scores}
    assert scores["sync_amplification"] > 0.02


# ---------------------------------------------------------------------------
# simulator: fast-preset signatures (default tier-1)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fast_runs():
    out = {}
    for n in (4, 64):
        out[n] = {
            "base": simulate(SimConfig.fast(n)),
            "coord": simulate(SimConfig.fast(n, coordination=True)),
        }
    return out


def test_fast_scaling_efficiency_decreases(fast_runs):
    assert fast_runs[64]["base"].throughput / 64 \
        < fast_runs[4]["base"].throughput / 4


def test_fast_instability_grows_with_scale(fast_runs):
    assert fast_runs[64]["base"].cv > fast_runs[4]["base"].cv


def test_fast_coordination_helps_at_scale(fast_runs):
    # At the truncated horizon the robust signature is the CV cut; the
    # throughput win needs the full paper horizon (slow section above).
    assert fast_runs[64]["coord"].cv < 0.8 * fast_runs[64]["base"].cv
    assert fast_runs[64]["coord"].throughput \
        > 0.95 * fast_runs[64]["base"].throughput


def test_fast_records_feed_diagnostics(fast_runs):
    rep = diagnose(fast_runs[64]["base"].per_rank_records())
    assert rep.n_ranks == 64
    assert {s.mode for s in rep.scores} == {
        "sync_amplification", "fabric_contention", "locality_variance",
        "runtime_jitter"}


def test_simulator_deterministic_given_seed():
    a = simulate(SimConfig.paper(8, coordination=False, seed=3))
    b = simulate(SimConfig.paper(8, coordination=False, seed=3))
    assert a.step_times == b.step_times


def test_pacing_bounded_in_simulation():
    res = simulate(SimConfig.paper(32, coordination=True))
    for rank_recs in res.records:
        meds = sorted(r.total_time for r in rank_recs)
        med = meds[len(meds) // 2]
        for rec in rank_recs:
            assert rec.pacing_delay <= 0.6 * med * 1.5  # frac=0.6 + slack


def _hand_topology(n_shared: int):
    """A topology with *exactly* ``n_shared`` shared links. The fat-tree
    and TPU-pod constructors cannot produce zero shared links, so the
    congestion model's no-shared-links edge case needs a hand-built one."""
    from repro.fabric.topology import Link, Topology
    links = {f"s{i}": Link(f"s{i}", 50.0, 5e-6, shared=True)
             for i in range(n_shared)}
    links["leaf"] = Link("leaf", 50.0, 5e-6, shared=False)
    return Topology(name=f"hand{n_shared}", n_ranks=2, links=links)


@pytest.mark.parametrize("n_shared", [0, 1, 3, 4])
def test_congestion_advance_preserves_gauss_stream(n_shared):
    """`CongestionModel.advance` inlines ``random.gauss`` (with its
    Box-Muller pair cache) for speed; the optimization is only sound if
    the RNG ends up in *exactly* the state ``n_shared`` sequential
    ``gauss(0, 1)`` draws would leave — including ``gauss_next`` — for
    every link-count parity:

      * 0 links: advance() must be a stream no-op, not eat a pair;
      * 1 / odd links: the cached second gaussian must survive across
        advance() boundaries and be consumed by the *next* call;
      * even links: the cache is empty at every boundary.

    ``getstate()`` captures the Mersenne state *and* ``gauss_next``, so
    equality here is the full stream-consistency property."""
    import random as _random
    seed = 7
    cm = CongestionModel(CongestionConfig(u_sigma=0.2),
                         _hand_topology(n_shared), seed=seed)
    ref = _random.Random(seed)
    assert len(cm.u) == n_shared
    for _ in range(7):
        cm.advance()
        for _ in range(n_shared):
            ref.gauss(0.0, 1.0)
        assert cm.rng.getstate() == ref.getstate()
