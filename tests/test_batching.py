"""Continuous-batching fleet invariants (workloads/events/placement).

The contracts this module pins:

  * **bit-compat** — ``batching="none"`` (the default) is the pre-fleet
    single-stream path; ``batching="continuous"`` with ``max_batch=1`` is
    its degenerate twin, bit-identical fingerprint included. (The golden
    fixtures in ``tests/test_golden_series.py`` separately pin "none"
    against the series recorded before fleets existed.)
  * **request conservation** — no request is ever lost: across batch
    joins, node failures mid-batch, shrink-by-replica recovery, and
    re-placement, ``requests_arrived == requests_done +
    requests_outstanding``.
  * **JSQ** — the join-shortest-queue router never routes to a strictly
    longer queue than the minimum at decision time.
  * **slo_aware placement** — latency-bound chunks pack whole into
    best-fit leaves (span 1) and fall back gracefully to compact packing
    when no leaf can host a chunk; SLO-less specs behave as ``compact``.
"""
import math

import pytest

from repro.fabric import (Arrival, InferenceSpec, JobSpec, NodeFailure,
                          Scenario, TopologySpec, fat_tree, place)
from repro.fabric.congestion import batch_bytes
from repro.fabric.placement import slo_aware, spanning_groups
from repro.fabric.scenario import ScenarioError, library

FABRIC64 = TopologySpec(kind="fat_tree", n_nodes=64, nodes_per_leaf=8)


def _fleet_scenario(spec, horizon=8.0, train=True, name="batching"):
    events = [Arrival(0.0, JobSpec("train", 12, placement="compact",
                                   grad_bytes=2e9))] if train else []
    events.append(Arrival(0.0, spec))
    return Scenario(name=name, topology=FABRIC64, events=tuple(events),
                    horizon=horizon)


# ---------------------------------------------------------------------------
# bit-compat: none == continuous @ max_batch=1
# ---------------------------------------------------------------------------


def test_default_batching_is_none():
    assert InferenceSpec("s", 4).batching == "none"
    assert InferenceSpec("s", 4).replicas == 1


def test_continuous_max_batch_1_is_bit_identical_to_none():
    """Capacity-1 continuous batching degenerates to the single stream:
    joins only on an empty server, every decode at occupancy 1 — the
    same arithmetic operation for operation, so the fingerprints match
    bit-exactly (this is the compatibility proof that both disciplines
    share one engine path rather than forking the model)."""
    base = dict(n_ranks=4, rate_rps=12.0, decode_tokens=6, slo_p99_s=0.5)
    single = _fleet_scenario(InferenceSpec("serve", batching="none",
                                           **base)).run()
    degenerate = _fleet_scenario(InferenceSpec("serve",
                                               batching="continuous",
                                               max_batch=1, **base)).run()
    assert single.fingerprint() == degenerate.fingerprint()


def test_continuous_batching_emits_batch_join_log_events():
    spec = InferenceSpec("serve", 4, batching="continuous", max_batch=8,
                         rate_rps=30.0, decode_tokens=6)
    res = _fleet_scenario(spec).run()
    joins = [e for e in res.log if e[1] == "batch_join"]
    assert joins, "continuous fleet under load never joined a batch"
    # none-mode fleets never emit joins (log kinds feed the fingerprint,
    # so this is also what keeps the golden fixtures replayable)
    quiet = _fleet_scenario(InferenceSpec("serve", 4, rate_rps=30.0,
                                          decode_tokens=6)).run()
    assert not [e for e in quiet.log if e[1] == "batch_join"]


# ---------------------------------------------------------------------------
# request conservation
# ---------------------------------------------------------------------------


def _assert_conserved(tenant):
    assert tenant.requests_arrived == tenant.requests_done \
        + tenant.requests_outstanding
    assert len(tenant.latencies) == tenant.requests_done
    assert tenant.tokens_done \
        == tenant.requests_done * tenant.spec.decode_tokens


def test_request_conservation_steady_state():
    spec = InferenceSpec("serve", 4, replicas=2, batching="continuous",
                         max_batch=4, router="jsq", rate_rps=25.0,
                         decode_tokens=6)
    res = _fleet_scenario(spec, horizon=10.0).run()
    serve = res.tenant("serve")
    assert serve.requests_done > 100
    _assert_conserved(serve)


def test_no_request_lost_on_failure_mid_batch():
    """A node dies under a two-replica fleet mid-run: the fleet shrinks
    by whole replicas, in-flight batch members restart from prefill on
    the survivor (keeping their arrival times, so the recovery stall is
    visible in their latency), and nothing is dropped."""
    spec = InferenceSpec("serve", 4, replicas=2, batching="continuous",
                         max_batch=4, router="jsq", rate_rps=20.0,
                         decode_tokens=6, nodes=tuple(range(8)),
                         slo_p99_s=0.5)
    scn = Scenario(name="fleet_failure", topology=FABRIC64,
                   events=(Arrival(0.0, spec), NodeFailure(3.0, 2)),
                   horizon=12.0)
    res = scn.run()
    serve = res.tenant("serve")
    assert any(e[1] == "replaced" for e in res.log), res.log
    assert len(serve.replica_spans) == 1          # shrunk 2 -> 1 replicas
    assert serve.requests_done > 50
    _assert_conserved(serve)
    # the recovery stall shows up in the affected requests' latencies
    assert max(serve.latencies) > serve.latency_quantile(0.5)


@pytest.mark.slow
def test_batching_horizon_conservation_and_stability():
    """Long-horizon continuous batching: conservation holds over
    thousands of requests and the fleet keeps absorbing the arrival rate
    (no unbounded queue growth at a rate the batch capacity covers)."""
    base = library.build("continuous_batching_relief")
    scn = Scenario.from_dict({**base.to_dict(), "horizon": 120.0,
                              "name": "batching_horizon"})
    serve = scn.run().tenant("serve")
    assert serve.requests_done > 4000
    _assert_conserved(serve)
    # open-loop stability: outstanding work stays a tiny fraction of the
    # served volume (the single-stream discipline diverges here)
    assert serve.requests_outstanding < 0.02 * serve.requests_done
    assert serve.slo_attainment > 0.9


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_jsq_never_routes_to_a_strictly_longer_queue():
    spec = InferenceSpec("serve", 2, replicas=3, batching="continuous",
                         max_batch=4, router="jsq", rate_rps=30.0,
                         decode_tokens=5)
    serve = _fleet_scenario(spec, horizon=8.0).run().tenant("serve")
    assert len(serve.routing_log) > 100
    for choice, depths in serve.routing_log:
        assert depths[choice] == min(depths), (choice, depths)


def test_round_robin_cycles_blind():
    spec = InferenceSpec("serve", 2, replicas=3, batching="continuous",
                         max_batch=4, router="round_robin", rate_rps=30.0,
                         decode_tokens=5)
    serve = _fleet_scenario(spec, horizon=6.0).run().tenant("serve")
    choices = [c for c, _ in serve.routing_log]
    assert choices[:6] == [0, 1, 2, 0, 1, 2]


def test_jsq_beats_round_robin_under_asymmetric_replicas():
    """With one replica straddling a leaf boundary (slower), JSQ diverts
    load to the fast replica and completes at least as many requests at
    a lower p99 than blind round-robin."""
    results = {}
    for router in ("jsq", "round_robin"):
        spec = InferenceSpec("serve", 6, replicas=2,
                             batching="continuous", max_batch=4,
                             router=router, rate_rps=20.0, decode_tokens=8,
                             slo_p99_s=0.15, placement="compact")
        scn = Scenario(
            name=f"router_{router}", topology=FABRIC64,
            events=(Arrival(0.0, JobSpec("train", 12, placement="compact",
                                         grad_bytes=6e9)),
                    Arrival(1.0, spec)),
            horizon=12.0)
        results[router] = scn.run().tenant("serve")
    jsq, rr = results["jsq"], results["round_robin"]
    assert jsq.requests_done >= rr.requests_done
    assert jsq.latency_quantile(0.99) < rr.latency_quantile(0.99)


# ---------------------------------------------------------------------------
# continuous batching dominates the single stream under load
# ---------------------------------------------------------------------------


def test_continuous_batching_dominates_single_stream_at_high_rate():
    """The acceptance claim, at test scale: at an arrival rate the single
    stream cannot sustain, continuous batching completes strictly more
    requests at strictly lower p99 — the canonical tradeoff curve's
    high-rate end (``benchmarks.run --only batching`` tables it)."""
    base = dict(n_ranks=4, replicas=2, router="jsq", rate_rps=40.0,
                decode_tokens=8, slo_p99_s=0.6)
    single = _fleet_scenario(
        InferenceSpec("serve", batching="none", **base),
        horizon=10.0).run().tenant("serve")
    batched = _fleet_scenario(
        InferenceSpec("serve", batching="continuous", max_batch=8, **base),
        horizon=10.0).run().tenant("serve")
    assert batched.requests_done > single.requests_done
    assert batched.latency_quantile(0.99) < single.latency_quantile(0.99)
    assert batched.slo_attainment > single.slo_attainment


def test_slo_aware_jsq_beats_compact_round_robin_on_noisy_neighbor():
    """The slo_placement library scenario vs its placement/router-blinded
    twin: slo_aware + JSQ measurably improves SLO attainment."""
    base = library.build("slo_placement")
    smart = base.run()
    d = base.to_dict()
    d["events"][1]["spec"]["placement"] = "compact"
    d["events"][1]["spec"]["router"] = "round_robin"
    d["name"] = "slo_placement_blind"
    blind = Scenario.from_dict(d).run()
    assert smart.slo_attainment()["serve"] \
        > blind.slo_attainment()["serve"]
    assert max(smart.tenant("serve").replica_spans) == 1
    assert max(blind.tenant("serve").replica_spans) > 1


# ---------------------------------------------------------------------------
# slo_aware placement unit behavior
# ---------------------------------------------------------------------------


def test_slo_aware_packs_chunks_whole_into_best_fit_leaves():
    topo = fat_tree(64, nodes_per_leaf=8)
    spec = InferenceSpec("s", 6, replicas=2, slo_p99_s=0.2)
    # leaf 0 full, leaf 1 half-taken: best fit for a 6-chunk is leaf 1's
    # mirror — the fullest leaf that still fits — then the next free leaf
    nodes = place("slo_aware", topo, 12, taken=range(10), spec=spec)
    chunks = [nodes[:6], nodes[6:]]
    for chunk in chunks:
        assert spanning_groups(topo, chunk) == 1
    assert set(nodes).isdisjoint(range(10))


def test_slo_aware_prefers_fullest_fitting_leaf():
    topo = fat_tree(64, nodes_per_leaf=8)
    spec = InferenceSpec("s", 6, replicas=1, slo_p99_s=0.2)
    # leaf 1 has exactly 6 free (10..15), leaves 2+ have 8: best fit is
    # leaf 1, preserving whole-leaf holes for trainers
    nodes = place("slo_aware", topo, 6, taken=range(10), spec=spec)
    assert nodes == list(range(10, 16))


def test_slo_aware_falls_back_gracefully_when_no_leaf_fits():
    topo = fat_tree(64, nodes_per_leaf=8)
    # a 10-rank chunk cannot fit any 8-node leaf: compact fallback, still
    # n distinct nodes, spanning > 1 (the tenant pays the shared tier)
    spec = InferenceSpec("s", 10, replicas=1, slo_p99_s=0.2)
    nodes = place("slo_aware", topo, 10, spec=spec)
    assert sorted(nodes) == list(range(10))
    assert spanning_groups(topo, nodes) == 2
    # fragmented pool: every leaf keeps <= 4 free nodes, chunk of 6
    taken = [nd for nd in range(64) if nd % 2 == 0]
    frag = place("slo_aware", topo, 6,
                 taken=taken, spec=InferenceSpec("s", 6, slo_p99_s=0.2))
    assert len(set(frag)) == 6
    assert set(frag).isdisjoint(taken)


def test_slo_aware_without_slo_degrades_to_compact():
    topo = fat_tree(64, nodes_per_leaf=8)
    assert place("slo_aware", topo, 12, taken=range(5)) \
        == place("compact", topo, 12, taken=range(5))
    assert slo_aware(topo, 12, list(range(64)),
                     spec=JobSpec("t", 12)) == list(range(12))


# ---------------------------------------------------------------------------
# spec validation + capacity accounting
# ---------------------------------------------------------------------------


def test_spec_validation_rejects_malformed_fleet_shapes():
    with pytest.raises(ValueError, match="batching"):
        InferenceSpec("s", 4, batching="sometimes")
    with pytest.raises(ValueError, match="max_batch"):
        InferenceSpec("s", 4, max_batch=0)
    with pytest.raises(ValueError, match="replicas"):
        InferenceSpec("s", 4, replicas=0)
    with pytest.raises(ValueError, match="decode_tokens"):
        InferenceSpec("s", 4, decode_tokens=-1)


def test_prefill_only_requests_complete_at_prefill():
    """decode_tokens=0 (prefill-only serving, e.g. embedding fleets):
    requests complete at the prefill finish — the pre-fleet path's
    behavior — in both batching modes, without a stray decode step."""
    for batching in ("none", "continuous"):
        spec = InferenceSpec("serve", 4, batching=batching, max_batch=4,
                             rate_rps=10.0, decode_tokens=0)
        serve = _fleet_scenario(spec, horizon=6.0,
                                train=False).run().tenant("serve")
        assert serve.requests_done > 20
        assert serve.tokens_done == 0
        assert not serve.decode_step_times
        _assert_conserved(serve)


def test_scenario_validates_router_and_replica_capacity():
    def scn(spec):
        return Scenario(name="v", topology=FABRIC64,
                        events=(Arrival(0.0, spec),), horizon=4.0)
    with pytest.raises(ScenarioError, match="router"):
        scn(InferenceSpec("s", 4, router="psychic"))
    # capacity is consumed per replica: 5 x 16 > 64
    with pytest.raises(ScenarioError, match="80 ranks"):
        scn(InferenceSpec("s", 16, replicas=5))
    # pinned fleets pin total_ranks nodes, not n_ranks
    with pytest.raises(ScenarioError, match="8 distinct"):
        scn(InferenceSpec("s", 4, replicas=2, nodes=tuple(range(4))))
    ok = scn(InferenceSpec("s", 4, replicas=2, nodes=tuple(range(8))))
    assert ok.events[0].spec.total_ranks == 8


def test_fleet_spec_json_round_trip():
    spec = InferenceSpec("serve", 4, replicas=3, batching="continuous",
                         max_batch=16, router="jsq", slo_p99_s=0.25)
    scn = Scenario(name="rt", topology=FABRIC64,
                   events=(Arrival(0.0, spec),), horizon=4.0)
    back = Scenario.from_json(scn.to_json())
    assert back.to_dict() == scn.to_dict()
    spec2 = back.events[0].spec
    assert (spec2.batching, spec2.max_batch, spec2.replicas, spec2.router) \
        == ("continuous", 16, 3, "jsq")


def test_batch_bytes_occupancy_weighting():
    assert batch_bytes(1.6e7, 1) == 1.6e7          # bit-exact anchor
    assert batch_bytes(1.6e7, 4) == 4 * 1.6e7
    assert math.isclose(batch_bytes(2e8, 3) / batch_bytes(2e8, 1), 3.0)
    with pytest.raises(ValueError):
        batch_bytes(1e6, -1)
