"""Integration tests: the full training driver (data -> step -> coordination
-> checkpoint/restart), serving, and crash-recovery semantics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import PacingConfig
from repro.launch.train import train
from repro.launch.serve import generate


# The three full train-loop runs below are the suite's heaviest individual
# tests (~45 s combined); the generate tests keep the train/serve stack and
# the sharding shim covered in default tier-1.
@pytest.mark.slow
def test_train_loss_decreases():
    res = train(arch="qwen2-7b", smoke=True, steps=30, seq_len=64,
                global_batch=4, log_every=0, seed=0)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert np.isfinite(res.final_loss)
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_train_checkpoint_resume_bitwise(tmp_path):
    """Train 10 steps straight vs 5 + restart + 5: identical loss stream."""
    kw = dict(arch="qwen2-vl-2b", smoke=True, seq_len=32, global_batch=2,
              log_every=0, seed=3)
    full = train(steps=10, **kw)
    d = str(tmp_path / "ck")
    train(steps=5, ckpt_dir=d, ckpt_every=5, **kw)
    resumed = train(steps=10, ckpt_dir=d, resume=True, **kw)
    np.testing.assert_allclose(resumed.losses, full.losses[5:], rtol=1e-5)


@pytest.mark.slow
def test_train_summary_has_phase_breakdown():
    res = train(arch="rwkv6-3b", smoke=True, steps=6, seq_len=32,
                global_batch=2, log_every=0)
    s = res.summary
    assert s["iters"] == 6.0
    assert s["mean_step"] > 0
    assert "useful_fraction" in s


def test_generate_greedy_deterministic():
    cfg_key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(cfg_key, (2, 8), 0, 512, dtype=jnp.int32)
    a, _ = generate(arch="stablelm-12b", prompt_tokens=prompts,
                    max_new_tokens=6, smoke=True, seed=1)
    b, _ = generate(arch="stablelm-12b", prompt_tokens=prompts,
                    max_new_tokens=6, smoke=True, seed=1)
    assert a.shape == (2, 14)
    assert jnp.array_equal(a, b)
    # generated ids in vocab range
    assert int(jnp.max(a)) < 512 and int(jnp.min(a)) >= 0


def test_generate_encdec():
    prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 6), 0, 512,
                                 dtype=jnp.int32)
    enc = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 128)) * 0.02
    toks, _ = generate(arch="seamless-m4t-large-v2", prompt_tokens=prompts,
                       max_new_tokens=4, smoke=True, enc_embeds=enc)
    assert toks.shape == (2, 10)
