"""Unit tests for the paper's coordination layer (core/)."""
import math
import random

import numpy as np
import pytest

from repro.configs.base import PacingConfig
from repro.core import (CollectiveTrace, CoordinationAgent, PacingController,
                        diagnose, expected_max_factor, summarize)
from repro.core.instrumentation import IterationRecord, PhaseRecorder
from repro.core.pacing import PacingBank


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt

    def advance(self, dt):
        self.t += dt


def mk_cfg(**kw):
    base = dict(enabled=True, window=8, cv_threshold=0.05,
                skew_threshold=0.05, max_delay_frac=0.5, gain=0.8,
                decay=0.8, warmup_iters=4)
    base.update(kw)
    return PacingConfig(**base)


# ---------------------------------------------------------------------------
# PacingController
# ---------------------------------------------------------------------------


def test_pacing_disabled_never_delays():
    c = PacingController(mk_cfg(enabled=False))
    for _ in range(20):
        c.observe(0.5, 1.0)
        assert c.decide().delay == 0.0


def test_pacing_inactive_during_warmup():
    c = PacingController(mk_cfg(warmup_iters=10))
    for _ in range(9):
        c.observe(0.5, 1.0)
        assert c.decide().delay == 0.0


def test_pacing_activates_on_persistent_skew():
    c = PacingController(mk_cfg())
    for _ in range(10):
        c.observe(0.3, 1.0)           # persistently 30% early
    d = c.decide()
    assert d.active and d.delay > 0.0
    # paces by gain * min(window earliness)
    assert d.delay == pytest.approx(0.8 * 0.3, rel=0.2)


def test_pacing_no_activation_below_threshold():
    c = PacingController(mk_cfg())
    for _ in range(20):
        c.observe(0.01, 1.0)          # 1% wait: below skew_threshold
    assert c.decide().delay == 0.0


def test_pacing_bounded_by_step_fraction():
    c = PacingController(mk_cfg(max_delay_frac=0.25, gain=1.0))
    for _ in range(10):
        c.observe(0.9, 1.0)           # enormous wait
        d = c.decide()
    assert d.delay <= 0.25 * 1.0 + 1e-9


def test_pacing_self_limits_when_imbalance_subsides():
    c = PacingController(mk_cfg())
    for _ in range(10):
        c.observe(0.3, 1.0)
        c.decide()
    assert c.current_delay > 0.0
    # imbalance disappears: the delay disengages geometrically (rate ~gain)
    deltas = []
    for _ in range(25):
        c.observe(0.0, 1.0)
        d = c.decide()
        deltas.append(d.delay)
    assert d.delay < 0.01
    assert all(b <= a + 1e-12 for a, b in zip(deltas, deltas[1:]))


def test_pacing_never_chases_transient_jitter():
    """A single spike of wait must not trigger pacing (min-window)."""
    c = PacingController(mk_cfg())
    for i in range(20):
        c.observe(0.5 if i == 12 else 0.0, 1.0)
        d = c.decide()
        assert d.delay == 0.0


def test_pacing_delay_nonnegative_property():
    import random
    rng = random.Random(0)
    c = PacingController(mk_cfg())
    for _ in range(200):
        c.observe(rng.uniform(0, 2), rng.uniform(0.5, 2))
        d = c.decide()
        assert d.delay >= 0.0
        assert d.delay <= 0.5 * 2 + 1e-9


# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------


def test_phase_recorder_accumulates_and_resets():
    clk = FakeClock()
    rec = PhaseRecorder(clock=clk)
    with rec.phase("compute"):
        clk.advance(0.2)
    with rec.phase("comm"):
        clk.advance(0.1)
    r = rec.finish(step=0)
    assert r.compute_time == pytest.approx(0.2)
    assert r.comm_time == pytest.approx(0.1)
    assert r.total_time == pytest.approx(0.3)
    r2 = rec.finish(step=1)
    assert r2.compute_time == 0.0


def test_collective_trace_wait_inference():
    clk = FakeClock()
    tr = CollectiveTrace(clock=clk)
    # first collective: pure transfer 0.1 (the floor)
    tr.enter(); clk.advance(0.1); tr.exit()
    # second: 0.4 inside => 0.3 inferred wait
    tr.enter(); clk.advance(0.4); tr.exit()
    assert tr.transfer_floor() == pytest.approx(0.1)
    assert tr.wait_estimate() == pytest.approx(0.3)


def test_agent_paces_with_injected_clock_and_sleep():
    clk = FakeClock()
    agent = CoordinationAgent(mk_cfg(warmup_iters=2), clock=clk,
                              sleep=clk.sleep, comm_floor=0.0)
    slept_before = clk.t
    for step in range(12):
        def work():
            clk.advance(0.1 if step % 1 == 0 else 0.1)
            return None
        agent.timed_step(work)
        agent.recorder.add("wait", 0.3)      # pretend barrier wait
        agent.end_iteration(step, step_time=0.4)
    assert agent.controller.activations > 0
    assert clk.t > slept_before
    s = agent.summary()
    assert s["pacing_activations"] > 0


# ---------------------------------------------------------------------------
# diagnostics / taxonomy
# ---------------------------------------------------------------------------


def _mk_records(n_ranks, n_iters, compute_fn, wait_fn, comm=0.05):
    per_rank = []
    for r in range(n_ranks):
        recs = []
        for t in range(n_iters):
            c = compute_fn(r, t)
            w = wait_fn(r, t)
            recs.append(IterationRecord(step=t, compute_time=c,
                                        comm_time=comm, wait_time=w,
                                        total_time=c + comm + w))
        per_rank.append(recs)
    return per_rank


def test_diagnose_flags_locality_variance():
    # rank 3 persistently slow: same ranks slow every iteration
    recs = _mk_records(4, 50,
                       compute_fn=lambda r, t: 0.2 + (0.15 if r == 3 else 0),
                       wait_fn=lambda r, t: 0.15 if r != 3 else 0.0)
    rep = diagnose(recs, transfer_floor=0.05)
    assert rep.dominant in ("locality_variance", "sync_amplification")
    scores = {s.mode: s.score for s in rep.scores}
    assert scores["locality_variance"] > 0.2


def test_diagnose_flags_contention():
    import math
    # comm time far above floor, correlated across ranks per iteration
    recs = []
    for r in range(4):
        rr = []
        for t in range(50):
            comm = 0.3 + 0.2 * math.sin(t / 3.0)
            rr.append(IterationRecord(step=t, compute_time=0.1,
                                      comm_time=comm, wait_time=0.0,
                                      total_time=0.1 + comm))
        recs.append(rr)
    rep = diagnose(recs, transfer_floor=0.05)
    assert rep.dominant == "fabric_contention"


def test_expected_max_factor_monotone():
    vals = [expected_max_factor(n) for n in (2, 4, 16, 64, 256)]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    assert expected_max_factor(64) == pytest.approx(math.sqrt(2 * math.log(64)))


def test_summarize_cv():
    recs = [IterationRecord(step=i, compute_time=0.1, total_time=0.2)
            for i in range(10)]
    s = summarize(recs)
    assert s["cv_step"] == pytest.approx(0.0)
    assert s["mean_step"] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# PacingBank: vectorized controllers, float-exact vs the scalar reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [2, 5, 6, 8, 32])
def test_bank_matches_scalar_controllers_exactly(window):
    """The engine swaps N per-rank controllers for one PacingBank; the
    single-job bit-equality contract with the reference loop only survives
    if the bank's delays are the *same floats* — not approximately so —
    for any window length (including >= 8, where numpy's pairwise axis
    sums would round differently than Python's sum)."""
    cfg = mk_cfg(window=window, skew_threshold=0.04, gain=0.85,
                 max_delay_frac=0.6)
    n = 16
    ctrls = [PacingController(cfg) for _ in range(n)]
    bank = PacingBank(cfg, n)
    rng = random.Random(3)
    for _ in range(150):
        waits = [abs(rng.gauss(0.01, 0.02)) - 0.005 for _ in range(n)]
        steps = [0.2 + rng.gauss(0.0, 0.02) for _ in range(n)]
        scalar = []
        for r in range(n):
            ctrls[r].observe(waits[r], steps[r])
            scalar.append(ctrls[r].decide().delay)
        bank.observe(np.asarray(waits), np.asarray(steps))
        assert bank.decide().tolist() == scalar
    assert bank.activations.tolist() == [c.activations for c in ctrls]


def test_bank_respects_warmup_and_disabled():
    cfg = mk_cfg(enabled=False)
    bank = PacingBank(cfg, 4)
    bank.observe(np.full(4, 0.5), np.full(4, 0.2))
    assert bank.decide().tolist() == [0.0] * 4
    cfg = mk_cfg(warmup_iters=10)
    bank = PacingBank(cfg, 4)
    for _ in range(9):
        bank.observe(np.full(4, 0.5), np.full(4, 0.2))
        assert bank.decide().tolist() == [0.0] * 4


def test_bank_matches_scalar_on_nan_and_negative_observations():
    """Regression (backend PR): NaN wait/step observations — a dead
    rank's sentinel, or an uninitialized timer — must sanitize to 0.0 on
    *both* paths. The bank's old ``np.maximum(0.0, x)`` propagated NaN
    while the scalar controller's ``_clamp`` kept 0.0, silently breaking
    the bit-equality contract between them; both now use the
    ``where(x > 0, x, 0)`` form, so the two stay float-exact even under
    adversarial inputs."""
    cfg = mk_cfg(window=6, skew_threshold=0.04)
    n = 8
    ctrls = [PacingController(cfg) for _ in range(n)]
    bank = PacingBank(cfg, n)
    rng = random.Random(11)
    bad = [float("nan"), -0.5, 0.0]
    for _ in range(80):
        waits = [rng.choice(bad) if rng.random() < 0.3
                 else abs(rng.gauss(0.02, 0.02)) for _ in range(n)]
        steps = [rng.choice(bad) if rng.random() < 0.2
                 else 0.2 + rng.gauss(0.0, 0.02) for _ in range(n)]
        scalar = []
        for r in range(n):
            ctrls[r].observe(waits[r], steps[r])
            scalar.append(ctrls[r].decide().delay)
        bank.observe(np.asarray(waits), np.asarray(steps))
        out = bank.decide()
        assert not np.isnan(out).any()
        assert out.tolist() == scalar
    assert bank.activations.tolist() == [c.activations for c in ctrls]
