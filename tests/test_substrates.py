"""Optimizer / data / checkpoint / fault-tolerance substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs.base import OptimizerConfig
from repro.data import Prefetcher, SyntheticLM
from repro.ft import (FailureDetector, HeartbeatConfig, RestartPolicy,
                      plan_elastic_mesh)
from repro.optim import (adamw_update, clip_by_global_norm,
                         compressed_pseudo_grad, cosine_lr, global_norm,
                         init_opt_state, quantize_roundtrip)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.01)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_grad_clip():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-3)


def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_weight_decay_mask():
    cfg = OptimizerConfig(lr=0.01, warmup_steps=1, total_steps=10,
                          weight_decay=10.0, grad_clip=1e9)
    params = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    state = init_opt_state(cfg, params)
    grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(p2["w"] - 1.0))) > 1e-4   # decayed
    assert float(jnp.max(jnp.abs(p2["scale"] - 1.0))) < 1e-6  # masked


def test_int8_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q = quantize_roundtrip(x)
    # blockwise symmetric int8: |err| <= blockmax/127/2 per element
    err = jnp.abs(q - x)
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of EF-compressed grads converges to sum of true grads."""
    key = jax.random.PRNGKey(1)
    true = [jax.random.normal(jax.random.fold_in(key, i), (256,)) * 0.01
            for i in range(50)]
    residual = None
    sent = []
    for g in true:
        q, residual = compressed_pseudo_grad({"g": g}, residual)
        sent.append(q["g"])
    total_true = sum(jnp.sum(g) for g in true)
    total_sent = sum(jnp.sum(s) for s in sent)
    # EF: cumulative transmitted signal tracks cumulative true signal
    assert float(jnp.abs(total_sent - total_true)) < 0.05 * \
        abs(float(total_true)) + 0.01


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_host_sharded():
    mk = lambda h: SyntheticLM(vocab_size=4096, seq_len=32, global_batch=8,
                               seed=11, num_hosts=2, host_index=h)
    a0, a1 = mk(0).batch(3), mk(1).batch(3)
    b0 = mk(0).batch(3)
    assert np.array_equal(a0["tokens"], b0["tokens"])
    assert not np.array_equal(a0["tokens"], a1["tokens"])
    assert a0["tokens"].shape == (4, 33)
    assert a0["tokens"].max() < 4096 and a0["tokens"].min() >= 0


def test_data_prefetcher_ordered_and_stops():
    src = SyntheticLM(vocab_size=128, seq_len=8, global_batch=2, seed=0)
    pf = Prefetcher(src, start_step=2, max_steps=5)
    for step in (2, 3, 4):
        assert np.array_equal(pf.next()["tokens"], src.batch(step)["tokens"])
    with pytest.raises(StopIteration):
        pf.next()
    pf.close()


def test_data_nontrivial_distribution():
    src = SyntheticLM(vocab_size=1000, seq_len=256, global_batch=4, seed=0)
    toks = src.batch(0)["tokens"]
    # zipfian: top tokens much more frequent than tail
    counts = np.bincount(toks.ravel(), minlength=1000)
    assert counts[:10].sum() > 5 * counts[500:510].sum()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree():
    return {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.ones((3,), jnp.bfloat16)},
            "stack": [jnp.zeros((2, 2)), jnp.full((1,), 7, jnp.int32)]}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree()
    mgr.save(10, tree, metadata={"next_step": 10}, block=True)
    assert mgr.latest_step() == 10
    restored, meta = mgr.restore(10, tree)
    assert meta["next_step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(5, _tree(), block=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_failure_detector_virtual_clock():
    t = {"now": 0.0}
    det = FailureDetector([0, 1, 2], HeartbeatConfig(timeout_s=10),
                          clock=lambda: t["now"])
    t["now"] = 5.0
    det.heartbeat(0)
    det.heartbeat(1)
    t["now"] = 12.0
    assert det.suspected() == [2]
    assert det.healthy() == [0, 1]


def test_restart_policy_backoff_and_reset():
    p = RestartPolicy(max_restarts=3, backoff_s=1.0, backoff_mult=2.0)
    assert p.next_delay() == 1.0
    assert p.next_delay() == 2.0
    p.record_success()
    assert p.next_delay() == 1.0
    p.next_delay()
    p.next_delay()
    assert p.next_delay() is None     # exhausted


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(512, model_parallel=16) == \
        ((2, 16, 16), ("pod", "data", "model"))
    assert plan_elastic_mesh(256, model_parallel=16) == \
        ((16, 16), ("data", "model"))
    # 255 survivors: drop to 240 usable = 15 DP groups
    shape, axes = plan_elastic_mesh(255, model_parallel=16)
    assert shape == (15, 16)
    # catastrophic: fewer than one model group
    shape, axes = plan_elastic_mesh(12, model_parallel=16)
    assert shape == (1, 8)
