"""Event-driven lifecycle engine: arrivals, departures, failures with
elastic re-placement, open-loop inference co-tenants, determinism, and the
virtual-clock wiring through repro.ft."""
import math
import statistics
import warnings

import pytest

from repro.fabric import (Arrival, Departure, InferenceSpec, JobSpec,
                          LifecycleEngine, NodeFailure, fat_tree)
from repro.ft import FailureDetector, HeartbeatConfig, simulated_clock_scope

HORIZON = 20.0


def _fabric():
    return fat_tree(64, nodes_per_leaf=8)


def _run(events, until=HORIZON, **kw):
    return LifecycleEngine(_fabric(), events, base_seed=0, **kw).run(until)


# ---------------------------------------------------------------------------
# arrivals: contention is overlap-gated
# ---------------------------------------------------------------------------


INCUMBENT = JobSpec("inc", 12, nodes=tuple(range(12)))


def test_arrival_on_shared_uplink_degrades_only_after_arrival():
    """A job arriving at t=8 on leaves 1-2 (shares up1 with the incumbent)
    leaves the incumbent's series bit-identical before the arrival and
    stretches it afterwards."""
    solo = _run([Arrival(0.0, INCUMBENT)]).tenant("inc").step_times
    duo = _run([Arrival(0.0, INCUMBENT),
                Arrival(8.0, JobSpec("late", 12, nodes=tuple(range(12, 24)),
                                     grad_bytes=4e9))]) \
        .tenant("inc").step_times
    k = next((i for i in range(min(len(solo), len(duo)))
              if solo[i] != duo[i]), None)
    assert k is not None, "shared-uplink co-tenant must perturb the series"
    # divergence starts only once the co-tenant's collectives exist:
    # the prefix before t=8 is exact
    assert sum(solo[:k]) >= 8.0 - solo[0] - 2 * max(solo)
    assert statistics.fmean(duo[k:]) > statistics.fmean(solo[k:])


def test_arrival_on_disjoint_links_is_bit_inert():
    """Per-tenant congestion streams + explicit flow contention: a co-tenant
    with no shared link in common changes *nothing* — the incumbent's
    series is bit-identical, not merely close."""
    solo = _run([Arrival(0.0, INCUMBENT)]).tenant("inc").step_times
    duo = _run([Arrival(0.0, INCUMBENT),
                Arrival(8.0, JobSpec("late", 12, nodes=tuple(range(40, 52)),
                                     grad_bytes=4e9))]) \
        .tenant("inc").step_times
    assert duo == solo


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def _full_scenario():
    return [
        Arrival(0.0, JobSpec("t0", 12, placement="compact", algo="auto")),
        Arrival(3.0, JobSpec("t1", 12, placement="compact",
                             grad_bytes=2e9)),
        Arrival(2.0, InferenceSpec("serve", 4, rate_rps=8.0)),
        NodeFailure(9.0, 3),
        Departure(15.0, "t1"),
    ]


def test_same_seed_and_events_are_bit_identical():
    """Same seed + same event list => bit-identical multi-tenant series,
    including across the mid-run failure, re-placement, and departure."""
    a = _run(_full_scenario())
    b = _run(_full_scenario())
    for ta, tb in zip(a.tenants, b.tenants):
        assert ta.name == tb.name
        if ta.kind == "training":
            assert ta.step_times == tb.step_times
            assert ta.nodes == tb.nodes
        else:
            assert ta.latencies == tb.latencies
    assert [e[:2] for e in a.log] == [e[:2] for e in b.log]


def test_different_seed_changes_series():
    a = LifecycleEngine(_fabric(), _full_scenario(), base_seed=0).run(HORIZON)
    b = LifecycleEngine(_fabric(), _full_scenario(), base_seed=1).run(HORIZON)
    assert a.tenant("t0").step_times != b.tenant("t0").step_times


# ---------------------------------------------------------------------------
# failure -> detection -> elastic re-place
# ---------------------------------------------------------------------------


def test_failure_triggers_elastic_replace_mid_run():
    res = _run([Arrival(0.0, JobSpec("job", 12, placement="compact")),
                NodeFailure(6.0, 2)], until=25.0)
    job = res.tenant("job")
    kinds = [e.kind for e in job.recovery.events]
    assert kinds == ["failure", "resume"]
    # shrank by one node, re-placed off the dead node, kept stepping
    assert len(job.nodes) == 11
    assert 2 not in job.nodes
    assert len(job.placements) == 2
    assert job.iters_done > 25
    # sanity of the series across the re-place: no NaNs, no negative or
    # zero step times
    assert all(s > 0.0 and math.isfinite(s)
               for s in job.step_times)
    # the stall+recovery shows up as one long step around detection
    assert max(job.step_times) > 3 * min(job.step_times)


def test_model_parallel_width_survives_failure():
    """plan_elastic_mesh keeps the model axis intact: an mp=4 job that
    loses a node drops a whole dp group (12 -> 8 ranks)."""
    res = _run([Arrival(0.0, JobSpec("job", 12, placement="compact",
                                     model_parallel=4)),
                NodeFailure(6.0, 2)], until=25.0)
    assert len(res.tenant("job").nodes) == 8


def test_failed_nodes_return_to_pool_minus_the_dead_one():
    """After the incumbent shrinks and re-places, a blocked arrival must be
    admitted on the freed capacity."""
    events = [
        Arrival(0.0, JobSpec("big", 60, placement="compact")),
        # 4 free nodes left; this arrival cannot fit and blocks
        Arrival(1.0, JobSpec("waiter", 6, placement="compact")),
        Departure(8.0, "big"),
    ]
    res = _run(events, until=16.0)
    blocked = [e for e in res.log if e[1] == "blocked"]
    assert blocked and "waiter" in blocked[0][2]
    waiter = res.tenant("waiter")
    assert waiter.arrived_t is not None and waiter.arrived_t >= 8.0
    assert len(waiter.step_times) > 0


def test_departure_of_blocked_tenant_cancels_the_arrival():
    """A tenant that departs while still waiting for capacity must never
    be admitted afterwards."""
    events = [
        Arrival(0.0, JobSpec("big", 60, placement="compact")),
        Arrival(1.0, JobSpec("waiter", 6, placement="compact")),
        Departure(5.0, "waiter"),
        Departure(8.0, "big"),
    ]
    res = _run(events, until=16.0)
    with pytest.raises(KeyError):
        res.tenant("waiter")
    assert any(k == "departure" and "waiter" in d for _, k, d in res.log)


def test_pinned_arrival_blocks_on_taken_and_rejects_on_dead():
    events = [
        Arrival(0.0, JobSpec("inc", 12, nodes=tuple(range(12)), iters=20)),
        # pinned onto the incumbent's nodes: blocks, admitted after it
        # finishes its 20 steps
        Arrival(1.0, JobSpec("pinned", 4, nodes=(0, 1, 2, 3))),
        # pinned onto a node that dies first: rejected outright
        NodeFailure(2.0, 40),
        Arrival(3.0, JobSpec("doomed", 4, nodes=(40, 41, 42, 43))),
    ]
    res = _run(events, until=25.0)
    pinned = res.tenant("pinned")
    assert pinned.arrived_t >= res.tenant("inc").departed_t
    assert len(pinned.step_times) > 0
    with pytest.raises(KeyError):
        res.tenant("doomed")
    assert any(k == "rejected" and "doomed" in d for _, k, d in res.log)


def test_detection_never_predates_the_failure():
    """A tenant whose step outlasts the heartbeat window must not log a
    detection timestamped before the node died."""
    res = _run([Arrival(0.0, JobSpec("slow", 12, nodes=tuple(range(12)),
                                     grad_bytes=8e9)),
                NodeFailure(5.5, 3)], until=20.0)
    detected = [t for t, k, _ in res.log if k == "detected"]
    assert detected and detected[0] >= 5.5


def test_inference_request_survives_a_replace():
    """The request in flight when a node dies is retried on the new
    placement with its original arrival time — it must not vanish from
    the open-loop accounting."""
    spec = InferenceSpec("serve", 4, nodes=(0, 1, 2, 3), rate_rps=6.0)
    solo = _run([Arrival(0.0, spec)], until=20.0).tenant("serve")
    failed = _run([Arrival(0.0, spec), NodeFailure(10.0, 1)],
                  until=20.0).tenant("serve")
    # the fleet shrank to 3 ranks but kept serving; the recovery stall
    # surfaces as a latency outlier rather than a dropped request
    assert len(failed.nodes) == 3
    assert failed.requests_done > 0
    stall_lat = max(failed.latencies)
    assert stall_lat > max(solo.latencies[:len(failed.latencies)])


def test_iters_budget_departs_and_frees_nodes():
    res = _run([Arrival(0.0, JobSpec("a", 8, placement="compact", iters=10)),
                Arrival(0.5, JobSpec("b", 60, placement="compact"))],
               until=12.0)
    a, b = res.tenant("a"), res.tenant("b")
    assert len(a.step_times) == 10
    assert a.departed_t is not None
    # b blocked until a's 8 nodes came back
    assert b.arrived_t >= a.departed_t
    assert len(b.step_times) > 0


# ---------------------------------------------------------------------------
# inference co-tenants
# ---------------------------------------------------------------------------


def test_inference_tenant_serves_open_loop():
    res = _run([Arrival(0.0, InferenceSpec("serve", 4, rate_rps=10.0,
                                           decode_tokens=8))], until=30.0)
    t = res.tenant("serve")
    assert t.requests_done > 100            # ~10 rps over 30 s
    assert t.tokens_done == 8 * t.requests_done
    assert all(lat > 0.0 and math.isfinite(lat) for lat in t.latencies)
    assert t.latency_quantile(0.99) >= t.latency_quantile(0.5) > 0.0


def test_training_cotenant_inflates_inference_latency():
    """Decode fleets share up0 with a heavy training job: the paper's
    latency-sensitive-co-tenant effect. Max-min keeps the decode flow at
    its bottleneck share, but the shared link is still half as fast."""
    serve = InferenceSpec("serve", 8, nodes=tuple(range(4, 12)),
                          rate_rps=4.0)
    solo = _run([Arrival(0.0, serve)], until=25.0).tenant("serve")
    duo = _run([Arrival(0.0, serve),
                Arrival(0.0, JobSpec("train", 12,
                                     nodes=(0, 1, 2, 3) + tuple(
                                         range(12, 20)),
                                     grad_bytes=4e9))],
               until=25.0).tenant("serve")
    assert duo.mean_latency > solo.mean_latency


# ---------------------------------------------------------------------------
# virtual-clock wiring (repro.ft satellite)
# ---------------------------------------------------------------------------


def test_wall_clock_detector_warns_inside_simulated_scope():
    with simulated_clock_scope():
        with pytest.warns(RuntimeWarning, match="wall clock"):
            FailureDetector([0, 1], HeartbeatConfig())
    # outside the scope the default stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        FailureDetector([0, 1], HeartbeatConfig())


def test_engine_threads_virtual_clock_without_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        _run([Arrival(0.0, JobSpec("job", 8)), NodeFailure(3.0, 1)],
             until=10.0)


def test_lifecycle_run_is_one_shot():
    eng = LifecycleEngine(_fabric(), [Arrival(0.0, JobSpec("a", 4))],
                          base_seed=0)
    eng.run(5.0)
    with pytest.raises(RuntimeError):
        eng.run(5.0)


def test_rejects_unknown_fairness():
    with pytest.raises(KeyError):
        LifecycleEngine(_fabric(), [], fairness="bogus")


def test_rejects_unknown_scheduler():
    with pytest.raises(KeyError):
        LifecycleEngine(_fabric(), [], scheduler="sjf")


# ---------------------------------------------------------------------------
# paper-horizon sweep stays out of default tier-1
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_long_horizon_mixed_cluster_stays_finite():
    events = [Arrival(float(5 * i), JobSpec(f"t{i}", 12,
                                            placement="compact",
                                            algo="auto"))
              for i in range(4)]
    events += [Arrival(2.0, InferenceSpec("serve", 8, rate_rps=12.0)),
               NodeFailure(40.0, 5), NodeFailure(90.0, 30)]
    res = _run(events, until=150.0)
    for t in res.training:
        assert all(s > 0.0 and math.isfinite(s) for s in t.step_times)
    assert res.tenant("serve").requests_done > 1000
