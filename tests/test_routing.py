"""Routing policies, SHARP in-network reduction, and link lifecycle events.

Pins the giga-scale fabric contracts:

  * ``ecmp_static`` is the default ROUTING policy and is bit-compatible
    with the single-path costs the goldens/baselines hold (route tokens
    resolve to one hashed member at compile time);
  * ``adaptive_spray`` re-splits shared-segment bytes across all parallel
    inter-pod paths and strictly improves the contended striped p99
    (`routing_rescue` vs the same population on static ECMP);
  * ``sharp`` compiles a switch-aggregated allreduce only when the
    topology's in-network capacity admits the payload, falling back to
    ring/tree when oversubscribed;
  * ``LinkFlap`` / ``LinkDegrade`` transiently derate named path segments
    in the lifecycle engine;
  * every policy registry reports *its own* registered names on an
    unknown-name ScenarioError.
"""
import dataclasses
import statistics

import pytest

from repro.fabric.collectives import (compile_schedule, select_algo,
                                      sharp_available)
from repro.fabric.engine import JobSpec
from repro.fabric.events import (Arrival, LifecycleEngine, LinkDegrade,
                                 LinkFlap)
from repro.fabric.policies import (FAIRNESS, PLACEMENTS, ROUTERS, ROUTING,
                                   SCHEDULERS, resolve_routing)
from repro.fabric.scenario import (Policies, Scenario, ScenarioError,
                                   TopologySpec, library)
from repro.fabric.topology import multi_pod
from repro.fabric.workloads import InferenceSpec

MP = TopologySpec(kind="multi_pod", n_pods=2, ranks_per_pod=32,
                  nodes_per_leaf=8, inter_pod_links=2)


def _p99(res, tenant):
    s = sorted(res.series(tenant))
    return s[int(0.99 * (len(s) - 1))]


# ---------------------------------------------------------------------------
# the ROUTING registry
# ---------------------------------------------------------------------------


def test_routing_registry_contents():
    assert "ecmp_static" in ROUTING.names()
    assert "adaptive_spray" in ROUTING.names()
    assert not resolve_routing(None).adaptive
    assert resolve_routing(None).name == "ecmp_static"
    assert resolve_routing("adaptive_spray").adaptive


def test_ecmp_static_choose_is_salt_hash():
    pol = resolve_routing("ecmp_static")
    members = ["pp0-1.0", "pp0-1.1", "pp0-1.2"]
    for salt in range(9):
        assert pol.choose(members, salt) == members[salt % 3]


def test_ecmp_static_is_bit_compatible_with_default():
    """routing=None and routing='ecmp_static' compile identical costs —
    the contract that keeps existing goldens/baselines valid."""
    topo = multi_pod(2, 32, nodes_per_leaf=8, inter_pod_links=2)
    ranks = list(range(24, 40))
    for algo in ("ring", "tree", "hierarchical"):
        a = compile_schedule(topo, ranks, 1e9, algo=algo)
        b = compile_schedule(topo, ranks, 1e9, algo=algo,
                             routing=resolve_routing("ecmp_static"))
        assert a.total_s(None) == b.total_s(None)
        assert a.cost(None).per_link_bytes == b.cost(None).per_link_bytes


def test_adaptive_spray_splits_across_members():
    """Under spray, inter-pod bytes land on every parallel member; under
    static ECMP they all land on the one hashed member."""
    topo = multi_pod(2, 32, nodes_per_leaf=8, inter_pod_links=2)
    ranks = list(range(24, 40))
    static = compile_schedule(topo, ranks, 1e9, algo="ring")
    spray = compile_schedule(topo, ranks, 1e9, algo="ring",
                             routing=resolve_routing("adaptive_spray"))
    sb = static.cost(None).per_link_bytes
    pb = spray.cost(None).per_link_bytes
    static_members = [ln for ln in sb if ln.startswith("pp")]
    spray_members = [ln for ln in pb if ln.startswith("pp")]
    assert len(static_members) == 1
    assert sorted(spray_members) == ["pp0-1.0", "pp0-1.1"]
    # spray reacts to observed member efficiency: degrading one member
    # shifts the bottleneck less than it would for the pinned static path
    eff_bad = {ln: (0.25 if ln == static_members[0] else 1.0)
               for ln in list(sb) + list(pb)}
    assert spray.total_s(eff_bad) < static.total_s(eff_bad)


def test_routing_rescue_strictly_improves_striped_p99():
    rescue = library.build("routing_rescue")
    assert rescue.policies.routing == "adaptive_spray"
    ecmp = dataclasses.replace(
        rescue, name="ecmp", policies=Policies(routing="ecmp_static"))
    r_spray = rescue.run()
    r_ecmp = ecmp.run()
    for tenant in ("primary", "interferer"):
        assert _p99(r_spray, tenant) < _p99(r_ecmp, tenant)
        assert statistics.fmean(r_spray.series(tenant)) \
            < statistics.fmean(r_ecmp.series(tenant))


def test_batched_backends_reject_adaptive_routing_eagerly():
    with pytest.raises(ScenarioError, match="static routes only"):
        Scenario(name="x", topology=MP,
                 jobs=(JobSpec("a", 16),),
                 policies=Policies(backend="jnp",
                                   routing="adaptive_spray"))


def test_counterfactual_sweep_falls_back_for_adaptive_routing():
    from repro.fabric.backend import counterfactual_sweep
    scn = Scenario(name="x", topology=MP,
                   jobs=(JobSpec("a", 16, nodes=tuple(range(24, 40))),),
                   policies=Policies(routing="adaptive_spray"),
                   iters=10, warmup=2)
    (res, backend), = counterfactual_sweep([scn])
    assert backend == "reference"
    assert len(res.series("a")) == 8


# ---------------------------------------------------------------------------
# sharp: switch-aggregated allreduce with bounded in-network capacity
# ---------------------------------------------------------------------------


def test_sharp_availability_follows_capacity():
    quiet = multi_pod(2, 32, nodes_per_leaf=8)
    assert not sharp_available(quiet, 1e6)          # capacity 0: never
    cap = multi_pod(2, 32, nodes_per_leaf=8, sharp_capacity_bytes=1e9)
    assert sharp_available(cap, 1e9)
    assert not sharp_available(cap, 1e9 + 1)        # oversubscribed
    assert not sharp_available(cap, 0.0)            # nothing to reduce


def test_sharp_compiles_and_falls_back():
    topo = multi_pod(2, 32, nodes_per_leaf=8, sharp_capacity_bytes=1e9)
    ranks = list(range(16))
    sched = compile_schedule(topo, ranks, 5e8, algo="sharp")
    assert sched.algo == "sharp" and sched.steps == 2
    assert sched.total_s(None) > 0.0
    # oversubscribed payload: sharp falls back to the better of ring/tree
    fb = compile_schedule(topo, ranks, 2e9, algo="sharp")
    assert fb.algo in ("ring", "tree")
    ring = compile_schedule(topo, ranks, 2e9, algo="ring")
    tree = compile_schedule(topo, ranks, 2e9, algo="tree")
    assert fb.total_s(None) == min(ring.total_s(None), tree.total_s(None))


def test_sharp_bytes_are_fan_in_independent():
    """In-network aggregation: each link carries one payload copy per
    phase regardless of how many ranks funnel through it."""
    topo = multi_pod(2, 32, nodes_per_leaf=8, sharp_capacity_bytes=1e9)
    sched = compile_schedule(topo, list(range(16)), 5e8, algo="sharp")
    for ln, b in sched.cost(None).per_link_bytes.items():
        assert b <= 2 * 5e8 + 1e-9, (ln, b)


def test_sharp_joins_auto_candidates_only_when_admitted():
    topo = multi_pod(2, 32, nodes_per_leaf=8, sharp_capacity_bytes=1e9)
    ranks = list(range(16))
    # explicit candidate list: taken as-is, sharp never sneaks in
    name, _ = select_algo(topo, ranks, 5e8, candidates=("ring",))
    assert name == "ring"
    # auto: sharp participates (and must win only by strictly lower cost)
    name_auto, sched_auto = select_algo(topo, ranks, 5e8)
    best = {a: compile_schedule(topo, ranks, 5e8, algo=a).total_s(None)
            for a in ("ring", "tree", "hierarchical", "sharp")}
    assert sched_auto.total_s(None) == min(best.values())


def test_sharp_scenario_algo_accepted():
    scn = Scenario(
        name="sharp", topology=dataclasses.replace(
            MP, sharp_capacity_bytes=1e9),
        jobs=(JobSpec("a", 16, algo="sharp"),),
        iters=10, warmup=2)
    res = scn.run()
    assert len(res.series("a")) == 8


# ---------------------------------------------------------------------------
# link lifecycle events
# ---------------------------------------------------------------------------


def _flap_scenario(extra=()):
    return Scenario(
        name="flap",
        topology=TopologySpec(kind="fat_tree", n_nodes=64, nodes_per_leaf=8),
        events=(Arrival(0.0, JobSpec("job", 12, nodes=tuple(range(12)),
                                     grad_bytes=2e9)),) + tuple(extra),
        horizon=12.0)


def test_link_flap_transiently_slows_the_tenant():
    quiet = _flap_scenario()
    flapped = _flap_scenario([LinkFlap(4.0, "up0", down_s=2.0)])
    rq = quiet.run()
    rf = flapped.run()
    assert statistics.fmean(rf.series("job")) \
        > statistics.fmean(rq.series("job"))
    assert max(rf.series("job")) > 3 * max(rq.series("job"))


def test_link_degrade_is_milder_than_flap():
    deg = _flap_scenario([LinkDegrade(4.0, "up0", factor=0.5,
                                      duration_s=2.0)])
    flap = _flap_scenario([LinkFlap(4.0, "up0", down_s=2.0)])
    rd = deg.run()
    rf = flap.run()
    assert max(rd.series("job")) < max(rf.series("job"))


def test_link_events_serialize_round_trip():
    scn = _flap_scenario([LinkFlap(4.0, "up0", down_s=2.0),
                          LinkDegrade(5.0, "spine", factor=0.25)])
    again = Scenario.from_dict(scn.to_dict())
    assert again == scn
    assert again.run().fingerprint() == scn.run().fingerprint()


def test_link_events_validate_targets_and_ranges():
    with pytest.raises(ScenarioError, match="unknown link"):
        _flap_scenario([LinkFlap(1.0, "up99", down_s=1.0)])
    with pytest.raises(ScenarioError, match="down_s"):
        _flap_scenario([LinkFlap(1.0, "up0", down_s=0.0)])
    with pytest.raises(ScenarioError, match="factor"):
        _flap_scenario([LinkDegrade(1.0, "up0", factor=1.5)])
    with pytest.raises(ScenarioError, match="duration_s"):
        _flap_scenario([LinkDegrade(1.0, "up0", factor=0.5,
                                    duration_s=-1.0)])


def test_batched_backends_still_reject_event_timelines():
    with pytest.raises(ScenarioError, match="static-jobs"):
        dataclasses.replace(_flap_scenario([LinkFlap(1.0, "up0", 1.0)]),
                            policies=Policies(backend="jnp"))


# ---------------------------------------------------------------------------
# every registry reports its own names on an unknown policy
# ---------------------------------------------------------------------------


def test_unknown_policy_errors_list_the_correct_registry():
    base = library.build("topology_contention")

    with pytest.raises(ScenarioError, match="unknown fairness mode") as e:
        dataclasses.replace(base, policies=Policies(fairness="nope"))
    for known in FAIRNESS.names():
        assert known in str(e.value)

    with pytest.raises(ScenarioError, match="unknown scheduler") as e:
        dataclasses.replace(base, policies=Policies(scheduler="nope"))
    for known in SCHEDULERS.names():
        assert known in str(e.value)

    with pytest.raises(ScenarioError, match="unknown routing policy") as e:
        dataclasses.replace(base, policies=Policies(routing="nope"))
    for known in ROUTING.names():
        assert known in str(e.value)

    jobs = (dataclasses.replace(base.jobs[0], nodes=None,
                                placement="nope"),)
    with pytest.raises(ScenarioError, match="unknown placement") as e:
        dataclasses.replace(base, jobs=jobs)
    for known in PLACEMENTS.names():
        assert known in str(e.value)

    with pytest.raises(ScenarioError, match="unknown router") as e:
        Scenario(
            name="r", topology=base.topology,
            events=(Arrival(0.0, InferenceSpec("serve", 8, rate_rps=1.0,
                                               router="nope")),),
            horizon=5.0)
    for known in ROUTERS.names():
        assert known in str(e.value)
