"""Dry-run machinery tests that don't need 512 devices: HLO collective
parsing, roofline arithmetic, model-FLOP/memory accounting, reduced-depth
probe construction."""
import pytest

from repro.configs import SHAPES_BY_NAME, get_model_config
from repro.launch.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                   RooflineTerms, active_param_count,
                                   model_flops, model_memory_bytes,
                                   parse_collective_bytes, shape_bytes)


def test_shape_bytes():
    assert shape_bytes("bf16", "2,3") == 12
    assert shape_bytes("f32", "128") == 512
    assert shape_bytes("pred", "") == 1
    assert shape_bytes("s8", "1000") == 1000


HLO = """
HloModule test
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %p0), replica_groups={}
  %ag = f32[16,128]{1,0} all-gather(f32[8,128]{1,0} %x), dimensions={0}
  %cp = bf16[4]{0} collective-permute(bf16[4]{0} %y)
  %dot = f32[8,8]{1,0} dot(f32[8,4] %a, f32[4,8] %b)
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO)
    by = out["bytes_by_op"]
    assert by["all-reduce"] == 8 * 128 * 2
    assert by["all-gather"] == 8 * 128 * 4      # operand, not result
    assert by["collective-permute"] == 4 * 2
    assert by["reduce-scatter"] == 0
    assert out["counts"]["all-reduce"] == 1
    assert out["total_bytes"] == 8 * 128 * 2 + 8 * 128 * 4 + 8


def test_roofline_terms_arithmetic():
    t = RooflineTerms(flops_per_device=PEAK_FLOPS, bytes_per_device=HBM_BW,
                      collective_bytes_per_device=2 * LINK_BW, chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(2.0)
    assert t.dominant == "collective"


def test_active_param_counts_match_published_scale():
    """Param counts from config arithmetic should land near published."""
    # qwen2-7b ~7.6B total
    tot, act = active_param_count(get_model_config("qwen2-7b"))
    assert 6.5e9 < tot < 9e9
    assert tot == act
    # deepseek-v3: 671B total / 37B active
    tot, act = active_param_count(get_model_config("deepseek-v3-671b"))
    assert 6.0e11 < tot < 7.5e11
    assert 3.0e10 < act < 4.5e10
    # mixtral 8x7B: ~47B total / ~13B active
    tot, act = active_param_count(get_model_config("mixtral-8x7b"))
    assert 4.2e11 / 10 < tot < 5.2e10
    assert 1.1e10 < act < 1.5e10


def test_model_flops_train_matches_6nd():
    cfg = get_model_config("qwen2-7b")
    shp = SHAPES_BY_NAME["train_4k"]
    tot, act = active_param_count(cfg)
    mf = model_flops(cfg, shp)
    toks = shp.global_batch * shp.seq_len
    assert mf >= 6 * act * toks
    assert mf < 6 * act * toks * 1.2            # attention adds < 20% at 4k


def test_model_memory_decode_dominated_by_weights_or_cache():
    cfg = get_model_config("stablelm-12b")
    shp = SHAPES_BY_NAME["decode_32k"]
    m = model_memory_bytes(cfg, shp, chips=256, dp=16, tp=16)
    assert m["total"] > 0
    assert m["weights"] + m["cache_read"] > 0.9 * m["total"]


def test_reduced_depth_probe_configs():
    from repro.launch.dryrun import reduced_depth
    cfg = get_model_config("jamba-v0.1-52b")
    c1, n = reduced_depth(cfg, 1)
    c2, _ = reduced_depth(cfg, 2)
    assert n == 4                      # 32 layers / period 8
    assert c1.num_layers == 8 and c2.num_layers == 16
    assert not c1.scan_layers
    # deepseek: 3-layer dense prefix preserved
    cfg = get_model_config("deepseek-v3-671b")
    c1, n = reduced_depth(cfg, 1)
    assert n == 58 and c1.num_layers == 4
    # encoder-decoder scales encoder proportionally
    cfg = get_model_config("seamless-m4t-large-v2")
    c1, n = reduced_depth(cfg, 1)
    assert c1.num_encoder_layers == 1 and c1.num_layers == 1


def test_long_context_cache_bytes_bounded_for_swa():
    cfg = get_model_config("mixtral-8x7b")
    long = SHAPES_BY_NAME["long_500k"]
    m = model_memory_bytes(cfg, long, chips=256, dp=16, tp=16)
    # SWA ring: cache reads bounded by window, not by the 524k context
    full = 524288 * cfg.padded_kv_heads() * cfg.resolved_head_dim() * 4
    assert m["cache_read"] < cfg.num_layers * cfg.sliding_window * \
        cfg.padded_kv_heads() * cfg.resolved_head_dim() * 4 * 1.1
    assert m["cache_read"] < full