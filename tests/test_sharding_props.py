"""Sharding-rule unit tests + hypothesis property tests for system
invariants (divisibility fallback, quantization bounds, pacing bounds,
elastic mesh plans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import PacingConfig
from repro.core.pacing import PacingController
from repro.ft import plan_elastic_mesh
from repro.launch import sharding as shd
from repro.optim import quantize_roundtrip


@pytest.fixture()
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_spec_basic(mesh):
    with shd.axis_rules(mesh):
        p = shd.resolve_spec((8, 16), ("batch", "heads"))
        assert p == jax.sharding.PartitionSpec(("data",), "model")


def test_resolve_spec_fallback_records(mesh):
    with shd.axis_rules(mesh):
        shd.resolve_spec((7,), ("heads",))   # 7 % 1 == 0 on 1-dev mesh: ok
        # simulate a 16-way model axis via a fake rule on data axis of size 1
    big = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(big):
        spec = shd.resolve_spec((8,), ("ff",))
        assert spec == jax.sharding.PartitionSpec("model")


def test_logical_identity_without_rules():
    x = jnp.ones((2, 3))
    assert shd.logical(x, "batch", None) is x


@settings(max_examples=200, deadline=None)
@given(dim=st.integers(1, 4096))
def test_fallback_divisibility_invariant(dim):
    """resolve_spec never assigns axes whose product doesn't divide the dim.

    (Uses the rule table against a virtual 16-way axis by checking the
    arithmetic helper directly — the live mesh here has 1 device.)
    """
    # arithmetic core of the fallback: drop trailing axes until divisible
    sizes = {"model": 16, "data": 16, "pod": 2}
    phys = ["pod", "data"]
    div = 32
    while phys and dim % div != 0:
        dropped = phys.pop()
        div //= sizes[dropped]
    assert div in (1, 2, 32)
    assert dim % div == 0


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=2048).map(np.asarray))
def test_quantize_roundtrip_property(xs):
    x = jnp.asarray(xs, jnp.float32)
    q = quantize_roundtrip(x)
    block_max = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(q - x))) <= block_max / 127.0 + 1e-4


@settings(max_examples=50, deadline=None)
@given(waits=st.lists(st.floats(0, 10, allow_nan=False), min_size=1,
                      max_size=200),
       steps=st.lists(st.floats(0.01, 10, allow_nan=False), min_size=1,
                      max_size=200))
def test_pacing_always_bounded_property(waits, steps):
    cfg = PacingConfig(window=8, max_delay_frac=0.5, warmup_iters=2)
    c = PacingController(cfg)
    n = min(len(waits), len(steps))
    meds = []
    for w, s in zip(waits[:n], steps[:n]):
        c.observe(w, s)
        meds.append(s)
        d = c.decide()
        med = sorted(c._steps)[len(c._steps) // 2]
        assert d.delay >= 0.0
        assert d.delay <= cfg.max_delay_frac * med + 1e-9


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 4096))
def test_elastic_mesh_plan_property(n):
    shape, axes = plan_elastic_mesh(n, model_parallel=16)
    used = 1
    for s in shape:
        used *= s
    assert used <= n
    assert len(shape) == len(axes)
    # model axis preserved whenever possible
    if n >= 16:
        assert shape[-1] == 16
