"""Regenerate the bundled synthetic traces under ``tests/traces/``.

Each trace is exported from a seeded generator scenario
(:func:`repro.fabric.trace.bundled_scenario`) run on the reference
backend, so regeneration is bit-reproducible: ``python
tests/traces/generate.py`` (or ``make traces``) rewrites the files and
``--check`` verifies the committed files match a fresh export without
touching them. The trace-replay baseline fixtures
(``tests/baselines/traces/``) pin what the importer fits from these
files — regenerate those too (``make baselines``) if a deliberate
engine change moves the traces.
"""
import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def trace_path(name: str) -> str:
    return os.path.join(HERE, f"{name}.json")


def main() -> int:
    sys.path.insert(0, os.path.join(HERE, "..", "..", "src"))
    from repro.fabric.trace import BUNDLED_TRACES, generate_bundled

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="verify committed traces match a fresh export")
    args = ap.parse_args()

    stale = []
    for name in BUNDLED_TRACES:
        fresh = generate_bundled(name).to_dict()
        path = trace_path(name)
        if args.check:
            if not os.path.exists(path):
                stale.append(f"{path}: missing")
                continue
            with open(path) as f:
                committed = json.load(f)
            if committed != fresh:
                stale.append(f"{path}: differs from a fresh export")
            else:
                print(f"ok {path}")
        else:
            with open(path, "w") as f:
                json.dump(fresh, f, indent=1)
                f.write("\n")
            print(f"wrote {path} ({len(fresh['records'])} records)")
    if stale:
        print("\n".join(stale), file=sys.stderr)
        print("regenerate with: python tests/traces/generate.py",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
