"""Golden trace-replay fixtures for the bundled traces.

For each trace under ``tests/traces/`` the importer's output is pinned
end to end: the fitted ``Scenario.to_dict()`` (what ``fit_trace``
recovered), the replay ``Result.fingerprint()``, and the
predicted-vs-observed error report (``Result.validate(trace)``) are
persisted as versioned JSON under ``tests/baselines/traces/`` with the
same float-hex discipline as the scenario-library baselines — any drift
in the fitters, the engines, or the bundled traces themselves fails
with a readable per-path diff.

Regenerate (only when a behavior change is intended and reviewed):

    make baselines            # regenerates these alongside the library set
    make baselines-check      # checks both sets
"""
import json
import os
import sys

import pytest

from repro.fabric import trace as trace_mod

import test_baselines  # _hexify / diff_paths / REGEN_HINT

TRACE_DIR = os.path.join(os.path.dirname(__file__), "traces")
FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "baselines", "traces")
FIXTURE_VERSION = 1


def trace_path(name: str) -> str:
    return os.path.join(TRACE_DIR, f"{name}.json")


def fixture_path(name: str) -> str:
    return os.path.join(FIXTURE_DIR, f"{name}.json")


def snapshot(name: str):
    """The fixture payload for one bundled trace (fresh fit + replay)."""
    tr = trace_mod.load_trace(trace_path(name))
    fit = trace_mod.fit_trace(tr)
    result = fit.scenario.run(backend="reference")
    validation = trace_mod.validate_result(result, tr)
    return {"version": FIXTURE_VERSION, "trace": name,
            "scenario": test_baselines._hexify(fit.scenario.to_dict()),
            "notes": list(fit.notes),
            "fingerprint": result.fingerprint(),
            "validation": test_baselines._hexify(validation.to_dict())}


def check(name: str):
    path = fixture_path(name)
    if not os.path.exists(path):
        return [f"$: no fixture recorded at {path}"]
    with open(path) as f:
        expected = json.load(f)
    return test_baselines.diff_paths(expected, snapshot(name))


@pytest.mark.parametrize("name", sorted(trace_mod.BUNDLED_TRACES))
def test_trace_fit_matches_fixture(name):
    drift = check(name)
    assert not drift, (
        f"{name}: trace fit drifted from tests/baselines/traces/{name}.json"
        f" — {test_baselines.REGEN_HINT}\n  " + "\n  ".join(drift))


def test_every_fixture_names_a_bundled_trace():
    on_disk = {f[:-5] for f in os.listdir(FIXTURE_DIR)
               if f.endswith(".json")}
    assert on_disk == set(trace_mod.BUNDLED_TRACES), (
        f"fixture files {sorted(on_disk)} != bundled traces "
        f"{sorted(trace_mod.BUNDLED_TRACES)} — {test_baselines.REGEN_HINT}")


def test_bundled_traces_match_their_generators():
    """The committed trace files are bit-identical to a fresh export of
    the seeded generator scenarios (tests/traces/generate.py --check)."""
    for name in trace_mod.BUNDLED_TRACES:
        with open(trace_path(name)) as f:
            committed = json.load(f)
        assert committed == trace_mod.generate_bundled(name).to_dict(), (
            f"{name}: tests/traces/{name}.json differs from a fresh "
            f"export — regenerate with `python tests/traces/generate.py`")


# ---------------------------------------------------------------------------
# regen / check entry points (wired into make baselines / baselines-check)
# ---------------------------------------------------------------------------


def regen() -> None:
    os.makedirs(FIXTURE_DIR, exist_ok=True)
    names = set(trace_mod.BUNDLED_TRACES)
    for stale in sorted(os.listdir(FIXTURE_DIR)):
        if stale.endswith(".json") and stale[:-5] not in names:
            os.remove(os.path.join(FIXTURE_DIR, stale))
            print(f"removed stale traces/{stale}")
    for name in sorted(names):
        with open(fixture_path(name), "w") as f:
            json.dump(snapshot(name), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {fixture_path(name)}")


def run_check() -> int:
    bad = 0
    for name in sorted(trace_mod.BUNDLED_TRACES):
        drift = check(name)
        if drift:
            bad += 1
            print(f"DRIFT traces/{name}:")
            for d in drift:
                print(f"  {d}")
        else:
            print(f"ok    traces/{name}")
    if bad:
        print(f"\n{bad} trace fixture(s) drifted from tests/baselines/"
              f"traces/ — {test_baselines.REGEN_HINT}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(run_check() if "--check" in sys.argv[1:] else (regen() or 0))
