"""Per-kernel validation: Pallas (interpret mode) and chunked-XLA paths vs the
pure-jnp oracles in ``repro.kernels.ref``, swept over shapes/dtypes, plus
gradient checks for the custom-VJP dispatch in ``repro.kernels.ops``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, xla_impl
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan as mamba_pallas
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_pallas
from repro.kernels.wkv6 import wkv6 as wkv6_pallas
from repro.kernels import ops

jax.config.update("jax_enable_x64", False)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Sk, H, KV, D, causal, window, q_offset)
    (1, 8, 8, 2, 2, 16, True, 0, 0),
    (2, 64, 64, 4, 2, 32, True, 0, 0),
    (2, 64, 64, 4, 1, 32, False, 0, 0),
    (1, 128, 128, 2, 2, 64, True, 32, 0),      # sliding window
    (1, 16, 80, 2, 2, 32, True, 0, 64),        # chunked prefill (q offset)
    (1, 40, 40, 3, 1, 24, True, 0, 0),         # non-pow2 everything
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_interpret(case, dtype):
    B, Sq, Sk, H, KV, D, causal, window, q_off = case
    kq, kk, kv = keys(3)
    q = jax.random.normal(kq, (B, Sq, H, D), dtype)
    k = jax.random.normal(kk, (B, Sk, KV, D), dtype)
    v = jax.random.normal(kv, (B, Sk, KV, D), dtype)
    want = ref.attention(q, k, v, causal=causal, window=window, q_offset=q_off)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_off, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_xla(case):
    B, Sq, Sk, H, KV, D, causal, window, q_off = case
    kq, kk, kv = keys(3, seed=1)
    q = jax.random.normal(kq, (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, Sk, KV, D), jnp.float32)
    v = jax.random.normal(kv, (B, Sk, KV, D), jnp.float32)
    want = ref.attention(q, k, v, causal=causal, window=window, q_offset=q_off)
    got = xla_impl.flash_attention_xla(q, k, v, causal=causal, window=window,
                                       q_offset=q_off, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_xla_grads_match_ref():
    B, S, H, KV, D = 2, 48, 4, 2, 16
    kq, kk, kv = keys(3, seed=2)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, KV, D))
    v = jax.random.normal(kv, (B, S, KV, D))

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=True) ** 2)

    def loss_xla(q, k, v):
        return jnp.sum(xla_impl.flash_attention_xla(q, k, v, causal=True,
                                                    block_k=16) ** 2)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(loss_xla, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_flash_attention_xla_sliding_window_grads():
    B, S, H, KV, D = 1, 64, 2, 2, 16
    kq, kk, kv = keys(3, seed=3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, KV, D))
    v = jax.random.normal(kv, (B, S, KV, D))
    gr = jax.grad(lambda q: jnp.sum(
        ref.attention(q, k, v, causal=True, window=16)))(q)
    gx = jax.grad(lambda q: jnp.sum(xla_impl.flash_attention_xla(
        q, k, v, causal=True, window=16, block_k=16)))(q)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_matches_ref_with_cache():
    B, S, H, KV, D = 2, 32, 4, 2, 16
    kq, kk, kv = keys(3, seed=4)
    q = jax.random.normal(kq, (B, 1, H, D))
    kc = jax.random.normal(kk, (B, S, KV, D))
    vc = jax.random.normal(kv, (B, S, KV, D))
    kv_len = jnp.array([20, 32], jnp.int32)
    # oracle: causal decode == full attention at q position kv_len-1
    want = ref.attention(q, kc, vc, causal=True,
                         q_offset=kv_len - 1, kv_len=kv_len)
    got = xla_impl.decode_attention_xla(q, kc, vc, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 32), (2, 8, 64), (1, 5, 3, 128),
                                   (7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_interpret(shape, dtype):
    kx, ks = keys(2, seed=5)
    x = jax.random.normal(kx, shape, dtype)
    s = jax.random.normal(ks, (shape[-1],), dtype)
    want = ref.rmsnorm(x, s)
    got = rmsnorm_pallas(x, s, block_rows=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

WKV_CASES = [
    # (B, S, H, K, V, chunk)
    (1, 8, 1, 8, 8, 4),
    (2, 33, 2, 16, 16, 8),        # ragged S vs chunk
    (1, 64, 3, 32, 16, 16),       # K != V
    (2, 16, 2, 8, 8, 16),         # single chunk
]


def wkv_inputs(B, S, H, K, V, seed=6, dtype=jnp.float32):
    kr, kk, kv, kw, ku, ks = keys(6, seed=seed)
    r = jax.random.normal(kr, (B, S, H, K), dtype)
    k = jax.random.normal(kk, (B, S, H, K), dtype)
    v = jax.random.normal(kv, (B, S, H, V), dtype)
    # decay in (0,1) with log w in [-2.7, -0.003): the range real RWKV-6
    # parameterizations produce (w = exp(-exp(raw)), raw in [-6, 1])
    raw = jax.random.uniform(kw, (B, S, H, K), minval=-6.0, maxval=1.0)
    w = jnp.exp(-jnp.exp(raw)).astype(dtype)
    u = jax.random.normal(ku, (H, K), dtype)
    s0 = jax.random.normal(ks, (B, H, K, V), jnp.float32) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_chunked_xla(case):
    B, S, H, K, V, chunk = case
    r, k, v, w, u, s0 = wkv_inputs(B, S, H, K, V)
    y_want, s_want = ref.wkv6(r, k, v, w, u, s0)
    y_got, s_got = xla_impl.wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", WKV_CASES[:2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_interpret(case, dtype):
    B, S, H, K, V, chunk = case
    r, k, v, w, u, s0 = wkv_inputs(B, S, H, K, V, dtype=dtype)
    y_want, s_want = ref.wkv6(r, k, v, w, u, s0)
    y_got, s_got = wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_want, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.slow
def test_wkv6_chunked_grads_match_ref():
    B, S, H, K, V = 1, 24, 2, 8, 8
    r, k, v, w, u, s0 = wkv_inputs(B, S, H, K, V, seed=7)

    def loss(fn):
        def f(r, k, v, w, u):
            y, s = fn(r, k, v, w, u, s0)
            return jnp.sum(y ** 2) + jnp.sum(s ** 2)
        return f

    g_ref = jax.grad(loss(ref.wkv6), argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    g_xla = jax.grad(loss(lambda *a: xla_impl.wkv6_chunked(*a, chunk=8)),
                     argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-3)


def test_wkv6_decode_step_matches_scan():
    B, S, H, K, V = 2, 5, 2, 8, 8
    r, k, v, w, u, s0 = wkv_inputs(B, S, H, K, V, seed=8)
    y_want, s_want = ref.wkv6(r, k, v, w, u, s0)
    state = s0
    ys = []
    for t in range(S):
        y, state = xla_impl.wkv6_decode(
            r[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], w[:, t:t + 1], u,
            state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

MAMBA_CASES = [
    # (B, S, D, N, chunk)
    (1, 8, 16, 4, 4),
    (2, 33, 32, 8, 8),
    (1, 64, 48, 16, 16),
]


def mamba_inputs(B, S, D, N, seed=9, dtype=jnp.float32):
    kx, kdt, ka, kb, kc, kd, kh = keys(7, seed=seed)
    x = jax.random.normal(kx, (B, S, D), dtype)
    dt = jax.nn.softplus(jax.random.normal(kdt, (B, S, D))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ka, (D, N)) * 0.5)
    Bm = jax.random.normal(kb, (B, S, N), dtype)
    C = jax.random.normal(kc, (B, S, N), dtype)
    Dd = jax.random.normal(kd, (D,))
    h0 = jax.random.normal(kh, (B, D, N), jnp.float32) * 0.1
    return x, dt, A, Bm, C, Dd, h0


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba_chunked_xla(case):
    B, S, D, N, chunk = case
    x, dt, A, Bm, C, Dd, h0 = mamba_inputs(B, S, D, N)
    y_want, h_want = ref.mamba_scan(x, dt, A, Bm, C, Dd, h0)
    y_got, h_got = xla_impl.mamba_chunked(x, dt, A, Bm, C, Dd, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", MAMBA_CASES[:2])
def test_mamba_pallas_interpret(case):
    B, S, D, N, chunk = case
    x, dt, A, Bm, C, Dd, h0 = mamba_inputs(B, S, D, N, seed=10)
    y_want, h_want = ref.mamba_scan(x, dt, A, Bm, C, Dd, h0)
    y_got, h_got = mamba_pallas(x, dt, A, Bm, C, Dd, h0, chunk=chunk,
                                block_d=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_mamba_chunked_grads_match_ref():
    B, S, D, N = 1, 16, 8, 4
    x, dt, A, Bm, C, Dd, h0 = mamba_inputs(B, S, D, N, seed=11)

    def loss(fn):
        def f(x, dt, Bm, C):
            y, h = fn(x, dt, A, Bm, C, Dd, h0)
            return jnp.sum(y ** 2) + jnp.sum(h ** 2)
        return f

    g_ref = jax.grad(loss(ref.mamba_scan), argnums=(0, 1, 2, 3))(x, dt, Bm, C)
    g_xla = jax.grad(loss(lambda *a: xla_impl.mamba_chunked(*a, chunk=8)),
                     argnums=(0, 1, 2, 3))(x, dt, Bm, C)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-3)


def test_mamba_decode_step_matches_scan():
    B, S, D, N = 2, 5, 8, 4
    x, dt, A, Bm, C, Dd, h0 = mamba_inputs(B, S, D, N, seed=12)
    y_want, h_want = ref.mamba_scan(x, dt, A, Bm, C, Dd, h0)
    h = h0
    ys = []
    for t in range(S):
        y, h = xla_impl.mamba_decode(x[:, t:t + 1], dt[:, t:t + 1], A,
                                     Bm[:, t:t + 1], C[:, t:t + 1], Dd, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_want), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ops dispatch layer
# ---------------------------------------------------------------------------


def test_ops_backend_selection_and_grad():
    ops.set_backend("xla")
    try:
        B, S, H, KV, D = 1, 16, 2, 1, 8
        kq, kk, kv = keys(3, seed=13)
        q = jax.random.normal(kq, (B, S, H, D))
        k = jax.random.normal(kk, (B, S, KV, D))
        v = jax.random.normal(kv, (B, S, KV, D))
        out = ops.attention(q, k, v)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        g = jax.grad(lambda q: jnp.sum(ops.attention(q, k, v)))(q)
        assert g.shape == q.shape and bool(jnp.all(jnp.isfinite(g)))
    finally:
        ops.set_backend("auto")


def test_ops_interpret_backend_grads_flow_through_custom_vjp():
    ops.set_backend("interpret")
    try:
        x = jax.random.normal(jax.random.PRNGKey(14), (4, 32))
        s = jnp.ones((32,))
        g = jax.grad(lambda x: jnp.sum(ops.rmsnorm(x, s) ** 2))(x)
        g_ref = jax.grad(lambda x: jnp.sum(ref.rmsnorm(x, s) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-4)
    finally:
        ops.set_backend("auto")


def test_wkv6_chunked_extreme_decay_stays_finite():
    """Decays below the LOGW_MIN clamp must not produce inf/nan (fwd or bwd)."""
    B, S, H, K, V = 1, 32, 1, 8, 8
    kr, kk, kv = keys(3, seed=20)
    r = jax.random.normal(kr, (B, S, H, K))
    k = jax.random.normal(kk, (B, S, H, K))
    v = jax.random.normal(kv, (B, S, H, V))
    w = jnp.full((B, S, H, K), 1e-9)          # log w ~ -20.7, well below clamp
    u = jnp.ones((H, K))
    y, s = xla_impl.wkv6_chunked(r, k, v, w, u, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))
    g = jax.grad(lambda r: jnp.sum(
        xla_impl.wkv6_chunked(r, k, v, w, u, chunk=16)[0] ** 2))(r)
    assert bool(jnp.all(jnp.isfinite(g)))
